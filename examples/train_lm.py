"""End-to-end training driver (deliverable b): trains a reduced-config model
from the assigned pool for a few hundred steps on CPU with the full
production stack — grad-accum train step, AdamW, checkpointing, restart, and
the fault-tolerant loop.  On TPU hardware, drop --reduced and pick a mesh.

Run:  PYTHONPATH=src python examples/train_lm.py [--arch mamba2-130m] [--steps 200]
"""

import argparse

from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()
    train_main([
        "--arch", args.arch, "--reduced",
        "--steps", str(args.steps),
        "--batch", str(args.batch),
        "--seq", str(args.seq),
        "--ckpt-every", "50",
    ])
