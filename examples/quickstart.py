"""Quickstart: AIDW interpolation with the Pallas kernels (60 seconds).

Builds a clustered synthetic elevation field, interpolates a query set with
the paper's tiled kernel (interpret mode on CPU, same call compiles for TPU),
and compares AIDW vs standard IDW accuracy on the known ground truth.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core.aidw import AIDWParams
from repro.data.spatial import clustered_points, uniform_points
from repro.engine import build_plan, execute, execute_with_stats
from repro.kernels import aidw, idw


def main():
    rng = np.random.default_rng(0)
    truth = lambda x, y: np.sin(4 * x) * np.cos(3 * y) + 0.5 * x

    # clustered samples of a smooth field (the regime AIDW was designed for)
    dx, dy, _ = clustered_points(4096, seed=1, n_clusters=24, spread=0.04)
    dz = truth(dx, dy).astype(np.float32)
    qx, qy, _ = uniform_points(2048, seed=2)
    q_truth = truth(qx, qy)

    params = AIDWParams(k=10, area=1.0)
    z_aidw, alpha = aidw(dx, dy, dz, qx, qy, params=params, area=1.0, impl="tiled", layout="soa")
    # impl="grid" buckets the data points into a uniform grid so Phase 1
    # (the kNN -> adaptive-alpha pass) only visits candidate neighbourhoods
    # instead of all m points — same answer, near-O(k) per query (DESIGN.md §4)
    z_grid, alpha_grid = aidw(dx, dy, dz, qx, qy, params=params, area=1.0, impl="grid")
    z_idw = idw(dx, dy, dz, qx, qy, alpha=2.0)

    # Serving more than one query batch?  Build the plan ONCE and reuse it:
    # everything shape- and occupancy-dependent (the grid snapshot, padded
    # layouts, candidate capacity) is captured at plan time, so execute() is
    # a pure jitted function — the second same-shape batch below reuses both
    # the snapshot and the compiled executable (DESIGN.md §6).
    plan = build_plan(dx, dy, dz, params=params, area=1.0, impl="grid")
    z_batch1, _ = execute(plan, qx, qy)                     # compiles once
    qx2, qy2, _ = uniform_points(2048, seed=3)
    z_batch2, _ = execute(plan, qx2, qy2)                   # jit cache hit

    # When does the fast path degrade?  execute_with_stats says.  Demo: a
    # uniform dataset with a serving-tuned (tight) candidate capacity, and a
    # batch that is mostly tile-local plus a full-bbox diagonal — the
    # diagonal's Morton block straddles the grid's Z-order seams, its
    # candidate rectangle overflows the capacity, and ONLY its queries are
    # ring-searched exactly (never wrong, just slower); the rest keep the
    # kernel fast path, and sparse blocks skip their all-sentinel candidate
    # tiles entirely (DESIGN.md §6).
    udx, udy, _ = uniform_points(4096, seed=4)
    udz = truth(udx, udy).astype(np.float32)
    tight = build_plan(udx, udy, udz, params=params, area=1.0, impl="grid",
                       query_occupancy=64.0, seam_level=0)
    local = (0.05 + 0.03 * rng.random((256, 2))).astype(np.float32)
    diag = np.linspace(0.02, 0.98, 256).astype(np.float32)
    sqx = np.concatenate([local[:, 0], diag])
    sqy = np.concatenate([local[:, 1], diag])
    _, _, stats = execute_with_stats(tight, sqx, sqy)
    print("seam-straddling batch diagnostics (execute_with_stats):")
    print(f"  overflow_blocks={int(stats['overflow_blocks'])} "
          f"overflow_queries={int(stats['overflow_queries'])} of {sqx.shape[0]} "
          f"(ring-searched exactly; the rest stay on the kernel fast path)")
    print(f"  skipped_tile_fraction={float(stats['skipped_tile_fraction']):.2f} "
          f"whole_batch_fallback={bool(stats['grid_fallback'])}")

    # What if EVERY batch overflows — the capacity model's occupancy
    # assumption was just wrong for this workload?  Serve through the
    # self-healing layer instead: a persistent-overflow streak triggers a
    # background re-plan at a bumped capacity and an atomic hot-swap; the
    # storm batches keep being served exactly (blend arms) on the old plan
    # while the build runs, and the swapped plan stops the overflow
    # (DESIGN.md §9; bitwise recovery proof in tests/serving).
    import warnings
    from repro.serving import CapacityReestimator, PlanRegistry

    healer = CapacityReestimator(PlanRegistry(), "quickstart", tight)
    storm_x = (rng.random(64) * 6 - 3).astype(np.float32)  # out-of-bbox
    storm_y = (rng.random(64) * 6 - 3).astype(np.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # the overflow-streak warning
        ovf = []
        while healer.state == "healthy" and len(ovf) < 10:
            _, _, s = healer.execute(storm_x, storm_y)
            ovf.append(int(s["overflow_queries"]))
        healer.join()                      # let the background re-plan land
        _, _, s = healer.execute(storm_x, storm_y)
        ovf.append(int(s["overflow_queries"]))
    print("self-healing serving (overflow storm -> re-plan -> hot-swap):")
    print(f"  overflow_queries per batch: {ovf} "
          f"(cand_capacity {tight.cand_capacity} -> {healer.plan.cand_capacity})")
    print(f"  state={healer.state} swaps={healer.stats()['swaps']}")

    # Phase 2 is a full m-point sweep in every exact impl.  phase2="farfield"
    # sweeps exact weights only inside a plan-chosen near radius and folds one
    # aggregate term per far cell — the first approximating path, so it ships
    # with an error budget: the plan proves a worst-case bound, and
    # farfield_error_report measures the real error against the Kahan oracle
    # (DESIGN.md §7; the budget is enforced by tests/engine/test_farfield.py).
    # The bound is meaningful when cells are compact relative to the near
    # distance — demo data: tight per-cell sensor clusters on a coarse grid
    # (on generic data the plan warns and reports an honest, weak bound).
    from repro.core.accuracy import farfield_error_report
    from repro.core.grid import build_grid
    import jax.numpy as jnp

    g = 12
    centers = (np.stack(np.meshgrid(np.arange(g), np.arange(g)), -1)
               .reshape(-1, 2) + 0.5) / g
    spts = centers[rng.integers(0, g * g, 4096)] + rng.normal(0, 0.003, (4096, 2))
    spts = np.clip(spts, 0.0, 1.0).astype(np.float32)
    sdz = truth(spts[:, 0], spts[:, 1]).astype(np.float32)
    sgrid = build_grid(jnp.asarray(spts[:, 0]), jnp.asarray(spts[:, 1]),
                       jnp.asarray(sdz), gx=g, gy=g)
    ff = build_plan(spts[:, 0], spts[:, 1], sdz, params=params, area=1.0,
                    impl="grid", grid=sgrid, phase2="farfield",
                    farfield_radius=3, block_q=64)
    fq = rng.random((512, 2)).astype(np.float32)
    report = farfield_error_report(ff, fq[:, 0], fq[:, 1])
    _, _, ff_stats = execute_with_stats(ff, fq[:, 0], fq[:, 1])
    print("far-field Phase 2 (near radius "
          f"{ff.farfield_radius} cells, proved bound {ff.farfield_bound:.3g}):")
    print(f"  near_points_mean={float(ff_stats['near_points_mean']):.0f} of m={ff.m}, "
          f"far_cells_mean={float(ff_stats['far_cells_mean']):.0f}")
    print(f"  measured max rel err {report['max_rel_err']:.2e} "
          f"(within_bound={report['within_bound']})")

    rmse = lambda z: float(np.sqrt(np.mean((np.asarray(z) - q_truth) ** 2)))
    print(f"data points: {dx.shape[0]}, queries: {qx.shape[0]}")
    print(f"adaptive alpha range: [{float(np.min(alpha)):.2f}, {float(np.max(alpha)):.2f}]")
    print(f"RMSE  AIDW (tiled kernel): {rmse(z_aidw):.4f}")
    print(f"RMSE  AIDW (grid kNN):     {rmse(z_grid):.4f}")
    print(f"RMSE  IDW  (alpha=2):      {rmse(z_idw):.4f}")
    print(f"grid vs tiled max |dz|:    {float(np.max(np.abs(np.asarray(z_grid) - np.asarray(z_aidw)))):.2e}")
    print(f"plan reuse max |dz|:       {float(np.max(np.abs(np.asarray(z_batch1) - np.asarray(z_grid)))):.2e}")
    print("AIDW adapts the decay power to local density; IDW uses one global power.")


if __name__ == "__main__":
    main()
