"""Multi-device ring-AIDW demo (beyond paper): data points AND queries
sharded over an 8-device mesh, the data shards rotating via collective
permute while each shard folds them into its running k-best / weight
partials.  Verifies exactness against the single-device oracle.

Runs itself in a subprocess with 8 simulated CPU devices.

Run:  PYTHONPATH=src python examples/distributed_aidw.py
"""

import os
import subprocess
import sys

WORKER = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.aidw import AIDWParams
from repro.core.distributed import ring_aidw
from repro.kernels.ref import aidw_ref
from repro.data.spatial import clustered_points, uniform_points

m, n = 4096, 2048
dx, dy, dz = clustered_points(m, seed=1)
qx, qy, _ = uniform_points(n, seed=2)
p = AIDWParams(k=10, area=1.0)

mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
print(f"mesh: {dict(mesh.shape)} -> ring over 8 shards; {m} data pts, {n} queries")
z, a = ring_aidw(mesh, dx, dy, dz, qx, qy, params=p, area=1.0, q_chunk=256, d_chunk=512)
z_ref, a_ref = aidw_ref(dx, dy, dz, qx, qy, p, 1.0)
err = float(np.abs(np.asarray(z) - np.asarray(z_ref)).max())
print(f"ring result vs single-device oracle: max |dz| = {err:.2e}")
hlo = jax.jit(lambda *args: ring_aidw(mesh, *args, params=p, area=1.0, q_chunk=256, d_chunk=512)) \
    .lower(dx, dy, dz, qx, qy).compile().as_text()
print("collective-permute ops in compiled HLO:", hlo.count("collective-permute"))
assert err < 5e-4
print("OK")
"""

if __name__ == "__main__":
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", WORKER], env=env)
    raise SystemExit(r.returncode)
