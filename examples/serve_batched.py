"""Batched serving example (deliverable b): prefill + KV-cache greedy decode
for any pool arch, the same serve_step the decode_32k/long_500k dry-run
cells lower onto the production mesh.

Run:  PYTHONPATH=src python examples/serve_batched.py [--arch mixtral-8x7b]
"""

import argparse

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    serve_main([
        "--arch", args.arch, "--reduced",
        "--batch", str(args.batch),
        "--prompt-len", "16",
        "--gen", str(args.gen),
    ])
