"""Accuracy ablation (EXPERIMENTS §Accuracy): f32 vs Kahan-compensated f32
vs f64 AIDW.  The paper's answer to f32 error is "use f64" (1/24 rate on its
GPU, nonexistent on TPU); Kahan-f32 recovers most of the gap at f32 speed.

Runs in a subprocess with JAX_ENABLE_X64=1 to obtain the f64 reference.

Run:  PYTHONPATH=src python examples/aidw_accuracy_ablation.py
"""

import os
import subprocess
import sys

WORKER = r"""
import numpy as np, jax.numpy as jnp
from repro.core.aidw import AIDWParams, aidw_interpolate
from repro.core.accuracy import aidw_interpolate_kahan, relative_rmse
from repro.kernels.ref import aidw_ref
from repro.data.spatial import clustered_points, uniform_points

m, n = 16384, 2048
dx64, dy64, dz64 = clustered_points(m, seed=3, dtype=np.float64)
qx64, qy64, _ = uniform_points(n, seed=4, dtype=np.float64)
p = AIDWParams(k=10, area=1.0)

z64, _ = aidw_ref(jnp.float64(dx64), jnp.float64(dy64), jnp.float64(dz64),
                  jnp.float64(qx64), jnp.float64(qy64), p, 1.0)
z64 = np.asarray(z64)

f32 = [jnp.float32(v) for v in (dx64, dy64, dz64, qx64, qy64)]
z32, _ = aidw_interpolate(*f32, p, area=1.0)
zk, _ = aidw_interpolate_kahan(*f32, p, area=1.0)

e32 = relative_rmse(jnp.float64(np.asarray(z32, np.float64)), jnp.float64(z64))
ek = relative_rmse(jnp.float64(np.asarray(zk, np.float64)), jnp.float64(z64))
print(f"points: m={m}, queries n={n}")
print(f"rel-RMSE vs f64:  plain f32   = {e32:.3e}")
print(f"rel-RMSE vs f64:  Kahan f32   = {ek:.3e}")
print(f"improvement: {e32/max(ek,1e-30):.1f}x at f32 throughput "
      f"(paper's f64 route costs 1/24 rate on its GPU; TPU has no native f64)")
"""

if __name__ == "__main__":
    env = dict(os.environ, JAX_ENABLE_X64="1", PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", WORKER], env=env)
    raise SystemExit(r.returncode)
