import numpy as np
import pytest


def require_hypothesis():
    """The one importorskip preamble for hypothesis-gated tests.

    Call at module top (before ``from hypothesis import ...``) or inside a
    test body.  Returns the imported module.  CI installs the ``dev`` extra
    and guards the suite's skip count, so these tests can never silently
    stop running there; local runs without the extra skip them.
    """
    return pytest.importorskip(
        "hypothesis", reason="dev extra not installed (pip install -e .[dev])"
    )


def make_points(m, n, seed=0, clustered=True, dtype=np.float32):
    """Test point sets. Clustered data exercises the full alpha range
    (uniform-random data saturates R(S0) > R_max => alpha == a5)."""
    rng = np.random.default_rng(seed)
    if clustered:
        nc = max(2, m // 64)
        centers = rng.random((nc, 2))
        pts = centers[rng.integers(0, nc, m)] + rng.normal(0, 0.02, (m, 2))
        pts = np.clip(pts, 0.0, 1.0)
    else:
        pts = rng.random((m, 2))
    dx, dy = pts[:, 0].astype(dtype), pts[:, 1].astype(dtype)
    dz = (np.sin(6 * pts[:, 0]) * np.cos(6 * pts[:, 1]) + 2.0).astype(dtype)
    qx = rng.random(n).astype(dtype)
    qy = rng.random(n).astype(dtype)
    return dx, dy, dz, qx, qy


@pytest.fixture
def points_small():
    return make_points(512, 200, seed=3)
