"""MoE layer properties: dropless == dense-over-all-experts reference,
capacity semantics, gate normalisation, aux losses."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import require_hypothesis
require_hypothesis()
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, smoke
from repro.models import params as pm
from repro.models.moe import moe, moe_spec

HSET = settings(deadline=None, max_examples=10)


def dense_ref(p, x, cfg):
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.moe_top_k
    xf = x.reshape(t, d)
    logits = (xf @ p["router"]["w"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = jax.lax.top_k(probs, k)
    gates = gates / gates.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("td,edf->etf", xf, p["wi_gate"])) * jnp.einsum(
        "td,edf->etf", xf, p["wi_up"]
    )
    y_all = jnp.einsum("etf,efd->etd", h, p["wo"])
    y = jnp.zeros((t, d))
    for j in range(k):
        y = y + gates[:, j][:, None] * y_all[eidx[:, j], jnp.arange(t)]
    return y.reshape(b, s, d)


def _cfg(name="mixtral-8x7b", cf=8.0):
    return dataclasses.replace(smoke(ARCHS[name]), moe_capacity_factor=cf)


@pytest.mark.parametrize("name", ["mixtral-8x7b", "qwen3-moe-30b-a3b"])
@given(seed=st.integers(0, 2**31 - 1))
@HSET
def test_dropless_matches_dense_reference(name, seed):
    cfg = _cfg(name)
    key = jax.random.PRNGKey(seed)
    p = pm.materialize(moe_spec(cfg), key)
    x = jax.random.normal(key, (2, 17, cfg.d_model), jnp.float32) * 0.5
    y, aux = moe(p, x, cfg)
    y_ref = dense_ref(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=5e-4, atol=5e-5)
    assert float(aux["lb_loss"]) >= 0.99  # lower-bounded by 1 at perfect balance
    assert float(aux["z_loss"]) >= 0


def test_capacity_drops_reduce_output_norm_not_nan():
    cfg = _cfg(cf=0.25)  # aggressive dropping
    key = jax.random.PRNGKey(0)
    p = pm.materialize(moe_spec(cfg), key)
    x = jax.random.normal(key, (2, 33, cfg.d_model), jnp.float32)
    y, _ = moe(p, x, cfg)
    assert bool(jnp.all(jnp.isfinite(y)))
    y_full, _ = moe(p, x, cfg, capacity_factor=16.0)
    # dropping can only remove contributions
    assert float(jnp.linalg.norm(y)) <= float(jnp.linalg.norm(y_full)) * 1.5


def test_single_token_decode_path():
    cfg = _cfg()
    key = jax.random.PRNGKey(1)
    p = pm.materialize(moe_spec(cfg), key)
    x = jax.random.normal(key, (4, 1, cfg.d_model), jnp.float32)
    y, _ = moe(p, x, cfg)
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense_ref(p, x, cfg)), rtol=5e-4, atol=5e-5)
