"""Per-architecture smoke tests (deliverable f): every assigned arch
instantiates a REDUCED same-family config and runs one forward + one
train step on CPU, asserting output shapes and finiteness.  The FULL
configs are exercised only via the dry-run (abstract, no allocation)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ShapeConfig, smoke
from repro.data.synthetic import batch_for_arch
from repro.models import build_model
from repro.models import params as pm
from repro.optim import AdamWConfig, adamw_init
from repro.train import make_train_step, make_prefill_step, make_serve_step, pad_caches

B, S = 2, 32
SMOKE_SHAPE = ShapeConfig("smoke", "train", S, B, accum_steps=2)


@pytest.fixture(scope="module")
def built():
    out = {}
    key = jax.random.PRNGKey(0)
    for name, arch in ARCHS.items():
        cfg = dataclasses.replace(smoke(arch), moe_capacity_factor=8.0)
        model = build_model(cfg)
        params = pm.materialize(model.spec(), key)
        out[name] = (cfg, model, params)
    return out


@pytest.mark.parametrize("name", list(ARCHS))
def test_forward_shapes_finite(built, name):
    cfg, model, params = built[name]
    batch = batch_for_arch(cfg, SMOKE_SHAPE, 0)
    kw = {"frames": batch["frames"]} if cfg.family == "audio" else {}
    h, caches, aux = model.apply(params, batch["tokens"], mode="train", extra=batch, **kw)
    assert h.shape == (B, S, cfg.d_model)
    logits = model.logits(params, h)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: non-finite logits"


@pytest.mark.parametrize("name", list(ARCHS))
def test_one_train_step(built, name):
    cfg, model, params = built[name]
    step_fn = make_train_step(model, cfg, SMOKE_SHAPE, opt=AdamWConfig(lr=1e-3), remat=True)
    opt_state = adamw_init(params)
    batch = batch_for_arch(cfg, SMOKE_SHAPE, 0)
    new_params, new_opt, metrics = jax.jit(step_fn)(params, opt_state, batch, jnp.int32(0))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, f"{name}: loss={loss}"
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually moved
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))), params, new_params)
    assert max(jax.tree.leaves(d)) > 0, f"{name}: no parameter update"
    assert int(new_opt["step"]) == 1


@pytest.mark.parametrize("name", list(ARCHS))
def test_loss_decreases_over_steps(built, name):
    """A few steps on a REPEATED batch must reduce the loss (end-to-end
    learning sanity per arch)."""
    cfg, model, params = built[name]
    step_fn = jax.jit(
        make_train_step(
            model, cfg, SMOKE_SHAPE, opt=AdamWConfig(lr=3e-3, weight_decay=0.0), remat=False,
            schedule=lambda step: 1.0,
        )
    )
    opt_state = adamw_init(params)
    batch = batch_for_arch(cfg, SMOKE_SHAPE, 0)
    losses = []
    p = params
    for i in range(8):
        p, opt_state, metrics = step_fn(p, opt_state, batch, jnp.int32(i))
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], f"{name}: {losses}"


@pytest.mark.parametrize("name", list(ARCHS))
def test_prefill_decode_matches_full_forward(built, name):
    """Serving correctness: prefill T tokens then decode token T == full
    forward on T+1 tokens (MoE at dropless capacity; SSM tol covers bf16
    chunked-vs-step drift)."""
    cfg, model, params = built[name]
    T, CAP = 24, 32
    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size)
    extra, kw = {}, {}
    if cfg.family == "audio":
        kw["frames"] = jax.random.normal(key, (B, T, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        extra["visual_embeds"] = jax.random.normal(key, (B, cfg.n_vis_tokens, cfg.d_model)) * 0.1

    h_full, _, _ = model.apply(params, tokens, mode="train", extra=extra, **kw)
    logits_full = model.logits(params, h_full)[:, -1]

    h_pre, caches, _ = model.apply(params, tokens[:, :T], mode="prefill", extra=extra, **kw)
    caches = pad_caches(caches, CAP)
    h_dec, new_caches, _ = model.apply(
        params, tokens[:, T : T + 1], mode="decode", caches=caches, pos=jnp.int32(T), extra=extra
    )
    logits_dec = model.logits(params, h_dec)[:, -1]
    scale = float(jnp.max(jnp.abs(logits_full))) + 1e-6
    err = float(jnp.max(jnp.abs(logits_full - logits_dec))) / scale
    tol = 0.05 if cfg.family in ("ssm", "hybrid") else 1e-3
    assert err < tol, f"{name}: rel err {err}"
    assert new_caches is not None


@pytest.mark.parametrize("name", ["minitron-4b", "mamba2-130m", "mixtral-8x7b", "whisper-medium"])
def test_serve_step_greedy_chain(built, name):
    """Three chained serve steps run and produce in-vocab tokens."""
    cfg, model, params = built[name]
    T, CAP = 8, 16
    key = jax.random.PRNGKey(4)
    tokens = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    kw = {"frames": jax.random.normal(key, (B, T, cfg.d_model), jnp.float32)} if cfg.family == "audio" else {}
    prefill = make_prefill_step(model, cfg)
    serve = jax.jit(make_serve_step(model, cfg))
    batch = {"tokens": tokens, **kw}
    logits, caches = prefill(params, batch)
    caches = pad_caches(caches, CAP)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    for i in range(3):
        tok, logits, caches = serve(params, caches, tok, jnp.int32(T + i))
        assert tok.shape == (B, 1)
        assert int(tok.max()) < cfg.vocab_size and int(tok.min()) >= 0
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned dimensions."""
    expect = {
        "whisper-medium": dict(d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096, vocab_size=51865, n_layers=24),
        "minitron-4b": dict(d_model=3072, n_heads=24, n_kv_heads=8, d_ff=9216, vocab_size=256000, n_layers=32),
        "stablelm-12b": dict(d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13824, vocab_size=100352, n_layers=40),
        "gemma3-27b": dict(d_model=5376, n_heads=32, n_kv_heads=16, d_ff=21504, vocab_size=262144, n_layers=62),
        "qwen1.5-32b": dict(d_model=5120, n_heads=40, n_kv_heads=40, d_ff=27392, vocab_size=152064, n_layers=64),
        "mamba2-130m": dict(d_model=768, vocab_size=50280, n_layers=24, ssm_state=128),
        "mixtral-8x7b": dict(d_model=4096, n_heads=32, n_kv_heads=8, vocab_size=32000, n_layers=32, n_experts=8, moe_top_k=2, d_ff_expert=14336),
        "qwen3-moe-30b-a3b": dict(d_model=2048, n_heads=32, n_kv_heads=4, vocab_size=151936, n_layers=48, n_experts=128, moe_top_k=8, d_ff_expert=768),
        "qwen2-vl-72b": dict(d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568, vocab_size=152064, n_layers=80),
        "zamba2-7b": dict(d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336, vocab_size=32000, n_layers=81, ssm_state=64),
    }
    for name, want in expect.items():
        cfg = ARCHS[name]
        for k, v in want.items():
            assert getattr(cfg, k) == v, f"{name}.{k}: {getattr(cfg, k)} != {v}"


def test_param_counts_in_band():
    """Total parameter counts sit near the advertised sizes."""
    bands = {
        "whisper-medium": (0.6e9, 1.0e9),
        "minitron-4b": (3.5e9, 5.2e9),
        "stablelm-12b": (10e9, 14e9),
        "gemma3-27b": (24e9, 30e9),
        "qwen1.5-32b": (30e9, 36e9),
        "mamba2-130m": (0.10e9, 0.16e9),
        "mixtral-8x7b": (44e9, 49e9),
        "qwen3-moe-30b-a3b": (28e9, 33e9),
        "qwen2-vl-72b": (68e9, 76e9),
        "zamba2-7b": (6e9, 9e9),
    }
    for name, (lo, hi) in bands.items():
        model = build_model(ARCHS[name])
        n = pm.count_params(model.spec())
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
