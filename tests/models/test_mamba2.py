"""Mamba2 SSD: chunked-matmul form vs literal sequential SSM recurrence,
decode step vs scan, property sweeps over chunk sizes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import require_hypothesis
require_hypothesis()
from hypothesis import given, settings, strategies as st

from repro.models.mamba2 import ssd_scan

HSET = settings(deadline=None, max_examples=10)


def seq_ref(xh, bm, cm, dt, a_log, init=None):
    B, L, H, P = xh.shape
    a = -np.exp(a_log)
    h = np.zeros((B, H, P, bm.shape[-1])) if init is None else init.copy()
    ys = []
    for t in range(L):
        dec = np.exp(dt[:, t] * a)
        h = h * dec[:, :, None, None] + np.einsum(
            "bn,bhp->bhpn", bm[:, t], dt[:, t][..., None] * xh[:, t]
        )
        ys.append(np.einsum("bn,bhpn->bhp", cm[:, t], h))
    return np.stack(ys, 1), h


def _data(seed, B=2, L=35, H=3, P=4, N=8):
    rng = np.random.default_rng(seed)
    xh = rng.normal(size=(B, L, H, P)).astype(np.float32)
    bm = rng.normal(size=(B, L, N)).astype(np.float32)
    cm = rng.normal(size=(B, L, N)).astype(np.float32)
    dt = (rng.random((B, L, H)) * 0.5).astype(np.float32)
    a_log = (rng.normal(size=(H,)) * 0.3).astype(np.float32)
    return xh, bm, cm, dt, a_log


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_ssd_matches_sequential(chunk):
    xh, bm, cm, dt, a_log = _data(0)
    y_ref, h_ref = seq_ref(xh, bm, cm, dt, a_log)
    y, h = ssd_scan(*map(jnp.asarray, (xh, bm, cm, dt, a_log)), chunk=chunk)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1), L=st.integers(1, 50))
@HSET
def test_ssd_property_sweep(seed, L):
    xh, bm, cm, dt, a_log = _data(seed, L=L)
    y_ref, h_ref = seq_ref(xh, bm, cm, dt, a_log)
    y, h = ssd_scan(*map(jnp.asarray, (xh, bm, cm, dt, a_log)), chunk=16)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-4, atol=2e-5)


def test_ssd_init_state_continuation():
    """Splitting a sequence at an arbitrary point and carrying the state must
    equal one uninterrupted pass (the prefill->decode contract)."""
    xh, bm, cm, dt, a_log = _data(7, L=40)
    args = list(map(jnp.asarray, (xh, bm, cm, dt, a_log)))
    y_full, h_full = ssd_scan(*args, chunk=8)
    cut = 23
    y1, h1 = ssd_scan(args[0][:, :cut], args[1][:, :cut], args[2][:, :cut], args[3][:, :cut], args[4], chunk=8)
    y2, h2 = ssd_scan(args[0][:, cut:], args[1][:, cut:], args[2][:, cut:], args[3][:, cut:], args[4], chunk=8, init_state=h1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)), np.asarray(y_full), rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(h2), np.asarray(h_full), rtol=2e-4, atol=2e-5)
