"""Windowed ring-buffer decode caches (§Perf iteration E): a sliding-window
layer's window-sized cache must produce BIT-IDENTICAL logits to the
full-length cache at every decode step (the ring holds exactly the window;
attention is permutation-invariant over key slots)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, smoke
from repro.models import build_model
from repro.models import params as pm
from repro.launch.specs import cache_abstract


def _zero_caches(model, cfg, batch, seq):
    abstract, _ = cache_abstract(model, cfg, batch, seq)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), abstract)


def test_windowed_equals_full_cache_decode():
    base = dataclasses.replace(smoke(ARCHS["gemma3-27b"]), sliding_window=4)
    cfg_full = dataclasses.replace(base, windowed_cache=False)
    cfg_win = dataclasses.replace(base, windowed_cache=True)
    model_f = build_model(cfg_full)
    model_w = build_model(cfg_win)
    key = jax.random.PRNGKey(0)
    params = pm.materialize(model_f.spec(), key)  # identical spec (caches differ)

    B, T = 2, 12
    caches_f = _zero_caches(model_f, cfg_full, B, T)
    caches_w = _zero_caches(model_w, cfg_win, B, T)
    # windowed local caches really are smaller
    sizes_f = sum(x.size for x in jax.tree.leaves(caches_f))
    sizes_w = sum(x.size for x in jax.tree.leaves(caches_w))
    assert sizes_w < sizes_f

    toks = jax.random.randint(key, (B, T), 0, cfg_full.vocab_size)
    for t in range(T):
        tok = toks[:, t : t + 1]
        h_f, caches_f, _ = model_f.apply(params, tok, mode="decode", caches=caches_f, pos=jnp.int32(t))
        h_w, caches_w, _ = model_w.apply(params, tok, mode="decode", caches=caches_w, pos=jnp.int32(t))
        lf = model_f.logits(params, h_f)
        lw = model_w.logits(params, h_w)
        np.testing.assert_allclose(
            np.asarray(lf), np.asarray(lw), rtol=2e-5, atol=2e-5,
            err_msg=f"step {t} (window wrap starts at t=4)",
        )


def test_mixtral_windowed_cache_decode_finite():
    cfg = dataclasses.replace(
        smoke(ARCHS["mixtral-8x7b"]), sliding_window=4, windowed_cache=True,
        moe_capacity_factor=8.0,
    )
    model = build_model(cfg)
    params = pm.materialize(model.spec(), jax.random.PRNGKey(1))
    B, T = 2, 10
    caches = _zero_caches(model, cfg, B, T)
    key = jax.random.PRNGKey(2)
    for t in range(T):
        tok = jax.random.randint(jax.random.fold_in(key, t), (B, 1), 0, cfg.vocab_size)
        h, caches, _ = model.apply(params, tok, mode="decode", caches=caches, pos=jnp.int32(t))
        assert bool(jnp.all(jnp.isfinite(model.logits(params, h))))
