"""Checkpointer: atomic roundtrip, integrity, keep-N GC, restore-into-target."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 4)).astype(np.float32)),
                   "stack": {"k": jnp.asarray(rng.normal(size=(3, 2)).astype(np.float32))}},
        "opt": {"m": jnp.zeros((4, 4)), "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree(1)
    ck.save(12, t)
    restored, step = ck.restore(t)
    assert step == 12
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(np.asarray(a), np.asarray(b)), t, restored)


def test_latest_and_keep(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    t = _tree(2)
    for s in (1, 5, 9):
        ck.save(s, t)
    assert ck.latest_step() == 9
    assert ck.all_steps() == [5, 9]  # keep=2 GC'd step 1


def test_corruption_detected(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree(3)
    path = ck.save(3, t)
    # flip bytes in one array file
    for name in os.listdir(path):
        if name.endswith(".npy"):
            a = np.load(os.path.join(path, name))
            np.save(os.path.join(path, name), a + 1)
            break
    with pytest.raises(IOError):
        ck.restore(t)


def test_restore_missing_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    with pytest.raises(FileNotFoundError):
        ck.restore(_tree())


def test_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree(4)
    ck.save(1, t)
    bad = jax.tree.map(lambda a: jnp.zeros((9, 9)) if a.ndim == 2 else a, t)
    with pytest.raises(ValueError):
        ck.restore(bad)
