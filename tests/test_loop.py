"""Fault-tolerant loop: checkpoint/restart on injected node failure,
bounded retries, straggler watchdog, deterministic replay."""

import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.train import InjectedFailure, LoopConfig, train_loop


def _toy_step(sleep=0.0):
    def step_fn(params, opt_state, batch, step):
        if sleep:
            time.sleep(sleep)
        params = {"w": params["w"] + batch["x"].mean()}
        return params, opt_state, {"loss": jnp.float32(1.0 / (step + 1))}

    return step_fn


def _batch_fn(step):
    return {"x": jnp.full((4,), float(step))}


def test_failure_recovery(tmp_path):
    ck = Checkpointer(str(tmp_path))
    fails = {10: 1}

    def injector(step):
        if fails.get(step, 0) > 0:
            fails[step] -= 1
            raise InjectedFailure(f"simulated node loss at {step}")

    params, opt, events = train_loop(
        _toy_step(), {"w": jnp.float32(0)}, {}, _batch_fn, ck,
        LoopConfig(num_steps=16, ckpt_every=4, log_every=100), failure_injector=injector,
        log=lambda *a: None,
    )
    assert events.restarts == 1
    # deterministic data => final state identical to a failure-free run
    p2, _, ev2 = train_loop(
        _toy_step(), {"w": jnp.float32(0)}, {}, _batch_fn, Checkpointer(str(tmp_path / "b")),
        LoopConfig(num_steps=16, ckpt_every=4, log_every=100), log=lambda *a: None,
    )
    assert ev2.restarts == 0
    np.testing.assert_allclose(float(params["w"]), float(p2["w"]), rtol=1e-6)


def test_persistent_failure_aborts(tmp_path):
    ck = Checkpointer(str(tmp_path))

    def injector(step):
        if step == 3:
            raise InjectedFailure("always dies")

    with pytest.raises(RuntimeError, match="exceeded max retries"):
        train_loop(
            _toy_step(), {"w": jnp.float32(0)}, {}, _batch_fn, ck,
            LoopConfig(num_steps=8, ckpt_every=2, max_retries=2, log_every=100),
            failure_injector=injector, log=lambda *a: None,
        )


def test_straggler_watchdog(tmp_path):
    ck = Checkpointer(str(tmp_path))
    slow = {12}

    def step_fn(params, opt_state, batch, step):
        time.sleep(0.3 if int(step) in slow else 0.01)
        return params, opt_state, {"loss": jnp.float32(1.0)}

    _, _, events = train_loop(
        step_fn, {"w": jnp.float32(0)}, {}, _batch_fn, ck,
        LoopConfig(num_steps=16, ckpt_every=100, straggler_factor=5.0, log_every=100),
        log=lambda *a: None,
    )
    assert events.stragglers >= 1
