"""Sharding rule engine: divisibility fallback, axis-reuse guard, rule sets."""

import numpy as np
import jax
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding.rules import RULE_SETS, spec_for


class FakeMesh:
    """Duck-typed mesh exposing .shape like jax Mesh (dict of axis sizes)."""

    def __init__(self, shape):
        self.shape = shape


M = FakeMesh({"pod": 2, "data": 16, "model": 16})
SINGLE = FakeMesh({"data": 16, "model": 16})


def test_divisible_dims_shard():
    spec = spec_for(("embed", "mlp"), (5120, 13824), RULE_SETS["train"], M)
    assert spec == P("data", "model")


def test_non_divisible_replicates():
    # 51865 (whisper vocab) is not divisible by 16 -> replicated
    spec = spec_for(("vocab", "embed"), (51865, 1024), RULE_SETS["train"], M)
    assert spec == P(None, "data")


def test_batch_uses_pod_and_data():
    spec = spec_for(("batch", "seq"), (256, 4096), RULE_SETS["train"], M)
    assert spec == P(("pod", "data"), None)


def test_batch_partial_prefix_when_pod_missing():
    spec = spec_for(("batch", "seq"), (256, 4096), RULE_SETS["train"], SINGLE)
    assert spec == P("data", None)


def test_batch_one_replicates():
    spec = spec_for(("batch", "seq"), (1, 4096), RULE_SETS["train"], M)
    assert spec == P(None, None)


def test_axis_never_used_twice():
    # both dims want "model"; the second must fall back to replication
    spec = spec_for(("heads", "kv"), (4096, 1024), RULE_SETS["train"], M)
    assert spec == P("model", None)


def test_long_rules_context_parallel_cache():
    spec = spec_for(("batch", "cache_seq", "kv", None), (1, 524288, 16, 128), RULE_SETS["long"], M)
    assert spec == P(None, ("data", "model"), None, None)


def test_decode_rules_cache_seq_on_model():
    spec = spec_for(("batch", "cache_seq", "kv", None), (128, 32768, 8, 128), RULE_SETS["decode"], M)
    assert spec == P(("pod", "data"), "model", None, None)


def test_logical_constraint_noop_outside_context():
    import jax.numpy as jnp

    from repro.sharding.rules import logical_constraint

    x = jnp.ones((4, 4))
    y = logical_constraint(x, ("batch", "embed"))
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
