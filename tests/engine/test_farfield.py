"""Far-field Phase 2 (build_plan(phase2="farfield"), DESIGN.md §7): the
error budget is ENFORCED, not just reported.

* the measured relative error (Kahan-oracle comparison,
  core.accuracy.farfield_error_report) must stay within the plan's proved
  worst-case bound on uniform / clustered / seam-straddling / out-of-bbox
  query distributions, in f32 and f64, deterministically AND under a
  hypothesis sweep of arbitrary point sets, z fields, radii and grids;
* the default phase2="exact" path must remain bitwise identical to a plan
  that never heard of far fields (Phase 1 shares one code path, so alpha is
  bitwise equal even on farfield plans);
* near-field overflow (batches sparser than the capacity model assumed)
  must route those queries to the exact sweep — bitwise — never to an
  unproved truncated near field;
* the model itself is sanity-pinned: zero dispersion => zero bound,
  monotone improvement with radius, inf when nothing is provable.
"""

import warnings

import numpy as np
import jax.numpy as jnp
import pytest

import jax

from repro.core.accuracy import farfield_error_report
from repro.core.aidw import AIDWParams
from repro.core.grid import build_grid, cell_aggregates
from repro.engine import build_plan, execute, execute_with_stats
from repro.engine.plan import _farfield_bound_model
from repro.errors import UnprovableRtolWarning
from conftest import require_hypothesis

P = AIDWParams(k=10, area=1.0)
DISTRIBUTIONS = ("uniform", "clustered", "seam", "out_of_bbox")


def _field(x, y):
    return (np.sin(6 * x) * np.cos(6 * y) + 2.0).astype(x.dtype)


def _cluster_data(seed, dtype=np.float32, gx=12, m=4000, sigma=0.003):
    """Tight per-cell clusters on a coarse user grid: small dispersion
    relative to the cell size, so the worst-case model proves a FINITE
    bound at small radii — the configuration where the budget test bites."""
    rng = np.random.default_rng(seed)
    centers = (np.stack(np.meshgrid(np.arange(gx), np.arange(gx)), -1)
               .reshape(-1, 2) + 0.5) / gx
    pts = centers[rng.integers(0, gx * gx, m)] + rng.normal(0, sigma, (m, 2))
    pts = np.clip(pts, 0.0, 1.0).astype(dtype)
    dx, dy = pts[:, 0], pts[:, 1]
    return dx, dy, _field(dx, dy)


def _queries(dist, nq, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    if dist == "uniform":
        q = rng.random((nq, 2))
    elif dist == "clustered":  # tile-local serving batch
        q = 0.35 + 0.12 * rng.random((nq, 2))
    elif dist == "seam":  # full diagonal: straddles every Morton seam level
        t = np.linspace(0.02, 0.98, nq)
        q = np.stack([t, t], 1) + rng.normal(0, 0.01, (nq, 2))
    elif dist == "out_of_bbox":
        q = rng.random((nq, 2)) * 6.0 - 3.0
    else:  # pragma: no cover
        raise ValueError(dist)
    q = q.astype(dtype)
    return q[:, 0], q[:, 1]


def _farfield_plan(dx, dy, dz, *, radius, gx=12, block_q=64):
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                   gx=gx, gy=gx)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # pathological-resolution warnings
        return build_plan(dx, dy, dz, params=P, area=1.0, impl="grid",
                          grid=g, phase2="farfield", farfield_radius=radius,
                          block_q=block_q)


# ----------------------------------------------------- error budget (tentpole)
@pytest.mark.parametrize("dist", DISTRIBUTIONS)
@pytest.mark.parametrize("radius", [2, 3])
def test_measured_error_within_proved_bound(dist, radius):
    """The acceptance property: measured max relative error <= the plan's
    farfield_rtol_bound, on all four query distributions, with a FINITE
    bound (the tight-cluster data keeps the model's tau small)."""
    dx, dy, dz = _cluster_data(seed=10)
    qx, qy = _queries(dist, 220, seed=11)
    plan = _farfield_plan(dx, dy, dz, radius=radius)
    assert np.isfinite(plan.farfield_bound), "this configuration must be provable"
    rep = farfield_error_report(plan, jnp.asarray(qx), jnp.asarray(qy))
    assert rep["bound"] == plan.farfield_bound
    assert rep["within_bound"], rep


@pytest.mark.parametrize("dist", ["uniform", "out_of_bbox"])
def test_measured_error_within_bound_f64(dist):
    """Same enforcement in f64 (no native f64 on the TPU target, but the
    interpret-mode path must honour the budget at both widths)."""
    with jax.experimental.enable_x64():
        dx, dy, dz = _cluster_data(seed=12, dtype=np.float64)
        qx, qy = _queries(dist, 150, seed=13, dtype=np.float64)
        plan = _farfield_plan(dx, dy, dz, radius=2)
        assert np.isfinite(plan.farfield_bound)
        rep = farfield_error_report(plan, jnp.asarray(qx), jnp.asarray(qy))
        assert rep["within_bound"], rep
        # f64 fp slack is ~1e-14: the measured error must be genuinely tiny
        assert rep["max_rel_err"] <= plan.farfield_bound + 1e-12


def test_error_budget_property():
    """Hypothesis sweep: arbitrary point sets, z values, query positions
    (inside and far outside the bbox), radii and grid resolutions — the
    measured error NEVER exceeds the proved bound."""
    require_hypothesis()
    from hypothesis import given, settings, strategies as st

    coord = st.floats(0.0, 1.0, allow_nan=False, width=32)
    zval = st.floats(-3.0, 3.0, allow_nan=False, width=32)
    qcoord = st.floats(-2.0, 3.0, allow_nan=False, width=32)

    @settings(deadline=None, max_examples=15)
    @given(
        pts=st.lists(st.tuples(coord, coord, zval), min_size=12, max_size=80),
        qs=st.lists(st.tuples(qcoord, qcoord), min_size=1, max_size=20),
        radius=st.sampled_from([1, 2, 3, 4]),
        gres=st.sampled_from([2, 4, 8]),
    )
    def run(pts, qs, radius, gres):
        _check_bound(np.asarray(pts, np.float32), np.asarray(qs, np.float32),
                     radius, gres)

    run()


def _check_bound(pts, qs, radius, gres):
    """Shared body of the property test — also driven deterministically
    below, so the check itself runs even where hypothesis is absent."""
    k = min(10, pts.shape[0])
    p = AIDWParams(k=k, area=1.0)
    g = build_grid(jnp.asarray(pts[:, 0]), jnp.asarray(pts[:, 1]),
                   jnp.asarray(pts[:, 2]), gx=gres, gy=gres)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        plan = build_plan(pts[:, 0], pts[:, 1], pts[:, 2], params=p, area=1.0,
                          impl="grid", grid=g, phase2="farfield",
                          farfield_radius=radius, block_q=64)
    rep = farfield_error_report(plan, jnp.asarray(qs[:, 0]), jnp.asarray(qs[:, 1]))
    assert rep["within_bound"], (rep, radius, gres, pts.shape)


def test_error_budget_deterministic_draws():
    """Deterministic instances of the property body: degenerate point sets
    (identical points, collinear, one point per cell), mixed-sign z, and
    queries far outside the bbox."""
    rng = np.random.default_rng(3)
    cases = [
        np.column_stack([np.full(16, 0.5), np.full(16, 0.5), np.full(16, 2.0)]),
        np.column_stack([np.linspace(0, 1, 24), np.linspace(0, 1, 24),
                         np.sin(np.arange(24.0))]),
        np.column_stack([rng.random(40), rng.random(40), rng.random(40) * 4 - 2]),
    ]
    qs = np.asarray([[0.5, 0.5], [-1.5, 2.5], [0.0, 1.0], [2.9, -1.9]])
    for pts in cases:
        for radius, gres in ((1, 2), (2, 4), (3, 8)):
            _check_bound(pts.astype(np.float32), qs.astype(np.float32),
                         radius, gres)


# -------------------------------------------------- model sanity / plan choice
def test_bound_model_shape():
    """Zero dispersion proves zero error; the bound improves monotonically
    with the radius; radii too small for any guarantee report inf."""
    assert _farfield_bound_model(3, 0.1, 4.0, 0.0, 0.5, 1.0) == 0.0
    bounds = [_farfield_bound_model(r, 0.1, 4.0, 0.005, 0.1, 1.0)
              for r in (1, 2, 4, 8, 16)]
    assert all(np.isfinite(bounds))
    assert all(b1 >= b2 for b1, b2 in zip(bounds, bounds[1:]))
    assert _farfield_bound_model(1, 0.1, 4.0, 0.2, 0.1, 1.0) == np.inf
    # z varying inside cells costs a first-order term: strictly worse than
    # the same geometry with cell-constant z
    assert (_farfield_bound_model(4, 0.1, 4.0, 0.005, 0.5, 1.0)
            > _farfield_bound_model(4, 0.1, 4.0, 0.005, 0.0, 1.0))


def test_plan_reports_bound_and_warns_when_unprovable():
    """farfield_rtol far below what a single-level aggregate can prove at a
    profitable radius: the plan warns, reports the honest bound, and the
    stats carry it; a huge rtol is chosen without warning."""
    rng = np.random.default_rng(5)
    dx, dy = rng.random(4096).astype(np.float32), rng.random(4096).astype(np.float32)
    dz = _field(dx, dy)
    with pytest.warns(UnprovableRtolWarning):
        plan = build_plan(dx, dy, dz, params=P, area=1.0, impl="grid",
                          phase2="farfield", farfield_rtol=1e-6)
    assert plan.farfield_radius >= 1
    qx = jnp.asarray(rng.random(300).astype(np.float32))
    qy = jnp.asarray(rng.random(300).astype(np.float32))
    _, _, stats = execute_with_stats(plan, qx, qy)
    assert float(stats["farfield_rtol_bound"]) == np.float32(plan.farfield_bound)
    assert {"near_points_mean", "far_cells_mean", "p2_overflow_queries"} < set(stats)
    # an easily-provable target (far set empty at worst) never warns
    dxc, dyc, dzc = _cluster_data(seed=6)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        plan2 = _farfield_plan(dxc, dyc, dzc, radius=3)
    assert np.isfinite(plan2.farfield_bound)


def test_farfield_validations():
    dx, dy, dz = _cluster_data(seed=7, m=256)
    with pytest.raises(ValueError, match="phase2"):
        build_plan(dx, dy, dz, params=P, area=1.0, impl="grid", phase2="fmm")
    with pytest.raises(ValueError, match="farfield"):
        build_plan(dx, dy, dz, params=P, area=1.0, impl="tiled",
                   phase2="farfield")
    with pytest.raises(ValueError, match="farfield_rtol"):
        build_plan(dx, dy, dz, params=P, area=1.0, impl="grid",
                   phase2="farfield", farfield_rtol=0.0)
    with pytest.raises(ValueError, match="farfield_radius"):
        build_plan(dx, dy, dz, params=P, area=1.0, impl="grid",
                   phase2="farfield", farfield_radius=0)


# ------------------------------------------------------- exact path untouched
def test_default_phase2_exact_is_bitwise_identical():
    """phase2 defaults to "exact" and produces bitwise-identical z AND alpha
    to an explicitly-exact plan; farfield plans share Phase 1 bitwise (alpha
    equal), only z may differ — and only within the bound."""
    dx, dy, dz = _cluster_data(seed=8)
    qx, qy = map(jnp.asarray, _queries("uniform", 300, seed=9))
    plan_default = build_plan(dx, dy, dz, params=P, area=1.0, impl="grid")
    plan_exact = build_plan(dx, dy, dz, params=P, area=1.0, impl="grid",
                            phase2="exact")
    assert plan_default.phase2 == "exact"
    z0, a0 = execute(plan_default, qx, qy)
    z1, a1 = execute(plan_exact, qx, qy)
    np.testing.assert_array_equal(np.asarray(z0), np.asarray(z1))
    np.testing.assert_array_equal(np.asarray(a0), np.asarray(a1))

    plan_ff = _farfield_plan(dx, dy, dz, radius=2, block_q=256)
    z2, a2 = execute(plan_ff, qx, qy)
    scale = float(np.max(np.abs(dz)))
    assert float(jnp.max(jnp.abs(z2 - z0))) / scale <= plan_ff.farfield_bound + 1e-5


def test_near_overflow_falls_back_to_exact_bitwise():
    """A batch sparser/wider than the near-capacity model assumed must NOT
    run on a truncated near field: every overflowed query's z is bitwise the
    exact full-sweep answer (same padded data, same alpha)."""
    rng = np.random.default_rng(14)
    dx, dy = rng.random(4096).astype(np.float32), rng.random(4096).astype(np.float32)
    dz = _field(dx, dy)
    p = AIDWParams(k=10, area=1.0, r_max=64.0)
    qx = jnp.asarray((rng.random(96) * 6 - 3).astype(np.float32))
    qy = jnp.asarray((rng.random(96) * 6 - 3).astype(np.float32))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        plan_ff = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                             phase2="farfield", farfield_radius=1,
                             query_occupancy=64.0)
        plan_ex = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                             query_occupancy=64.0)
    assert plan_ff.p2_capacity < plan_ff.m
    z_ff, a_ff, stats = execute_with_stats(plan_ff, qx, qy)
    z_ex, a_ex = execute(plan_ex, qx, qy)
    assert int(stats["p2_overflow_queries"]) == 96, "batch should overflow the near capacity"
    np.testing.assert_array_equal(np.asarray(z_ff), np.asarray(z_ex))
    np.testing.assert_array_equal(np.asarray(a_ff), np.asarray(a_ex))


# ----------------------------------------------------------- stats / no-retrace
def test_farfield_stats_static_and_no_retrace():
    dx, dy, dz = _cluster_data(seed=15)
    plan = _farfield_plan(dx, dy, dz, radius=2)
    rng = np.random.default_rng(16)
    qs = [(jnp.asarray(rng.random(200).astype(np.float32)),
           jnp.asarray(rng.random(200).astype(np.float32))) for _ in range(2)]
    n0 = execute_with_stats._cache_size()
    _, _, s1 = execute_with_stats(plan, *qs[0])
    n1 = execute_with_stats._cache_size()
    _, _, s2 = execute_with_stats(plan, *qs[1])
    n2 = execute_with_stats._cache_size()
    assert n1 == n0 + 1 and n2 == n1, "farfield stats must not retrace"
    assert set(s1) == set(s2)
    assert float(s1["far_cells_mean"]) > 0, "far path should engage in-bbox"
    assert float(s1["near_points_mean"]) > 0
    # the jitted stats carry the bound at the compute dtype
    assert float(s1["farfield_rtol_bound"]) == np.float32(plan.farfield_bound)


def test_cell_aggregates_consistency():
    """Aggregates match a numpy recomputation: counts, z-sums, centroids,
    dispersion and z-deviation maxima."""
    dx, dy, dz = _cluster_data(seed=17, m=600, gx=6)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz), gx=6, gy=6)
    agg = cell_aggregates(g)
    cx = np.clip((dx * 6).astype(int), 0, 5)
    cy = np.clip((dy * 6).astype(int), 0, 5)
    cid = cy * 6 + cx
    assert np.sum(np.asarray(agg.count)) == 600
    e_ref, zdev_ref = 0.0, 0.0
    for c in range(36):
        sel = cid == c
        if not sel.any():
            assert float(agg.count[c]) == 0.0
            continue
        np.testing.assert_allclose(float(agg.count[c]), sel.sum())
        np.testing.assert_allclose(float(agg.z_sum[c]), dz[sel].sum(), rtol=1e-5)
        np.testing.assert_allclose(float(agg.cent_x[c]), dx[sel].mean(), atol=1e-5)
        np.testing.assert_allclose(float(agg.cent_y[c]), dy[sel].mean(), atol=1e-5)
        e_ref = max(e_ref, np.sqrt((dx[sel] - dx[sel].mean()) ** 2
                                   + (dy[sel] - dy[sel].mean()) ** 2).max())
        zdev_ref = max(zdev_ref, np.abs(dz[sel] - dz[sel].mean()).max())
    np.testing.assert_allclose(agg.e_max, e_ref, rtol=1e-4)
    np.testing.assert_allclose(agg.z_dev_max, zdev_ref, rtol=1e-3, atol=1e-6)
    np.testing.assert_allclose(agg.z_abs_max, np.abs(dz).max(), rtol=1e-6)
