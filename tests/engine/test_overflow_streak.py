"""Concurrency and lifecycle of the persistent-overflow streak
(engine/execute.py: _note_overflow) — the trigger the serving layer's
capacity re-estimator consumes:

* concurrent execute_with_stats against ONE plan keeps a consistent streak
  (every batch counted, exactly one CapacityOverflowWarning at the
  threshold — no double-warn);
* a clean batch's reset is never lost (a fresh overflow run re-warns);
* streaks are independent across plan objects;
* the weakref.finalize cleanup drops the streak entry when the plan is
  garbage-collected (no id-keyed leak, no stale-streak aliasing when the
  id is reused).
"""

import gc
import threading
import warnings

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.aidw import AIDWParams
from repro.engine import build_plan, execute_with_stats
from repro.engine.execute import (
    PERSISTENT_OVERFLOW_BATCHES,
    _overflow_streaks,
)
from repro.errors import CapacityOverflowWarning

P = AIDWParams(k=10, area=1.0, r_max=64.0)


def _plan(seed=19, m=4096):
    rng = np.random.default_rng(seed)
    dx = rng.random(m).astype(np.float32)
    dy = rng.random(m).astype(np.float32)
    dz = (dx * dy).astype(np.float32)
    # dense assumed occupancy => sparse/out-of-bbox batches overflow
    return build_plan(dx, dy, dz, params=P, area=1.0, impl="grid",
                      query_occupancy=64.0)


def _storm(seed=20, n=64):
    rng = np.random.default_rng(seed)
    return (jnp.asarray((rng.random(n) * 6 - 3).astype(np.float32)),
            jnp.asarray((rng.random(n) * 6 - 3).astype(np.float32)))


def _clean(seed=21, n=64):
    rng = np.random.default_rng(seed)
    return (jnp.asarray((0.4 + 0.05 * rng.random(n)).astype(np.float32)),
            jnp.asarray((0.4 + 0.05 * rng.random(n)).astype(np.float32)))


def test_concurrent_batches_consistent_streak_single_warning():
    plan = _plan()
    qx, qy = _storm()
    execute_with_stats(plan, *_clean())  # compile + reset before the race
    n_threads = max(PERSISTENT_OVERFLOW_BATCHES + 2, 6)
    barrier = threading.Barrier(n_threads)
    errors = []

    def serve():
        try:
            barrier.wait()
            _, _, st = execute_with_stats(plan, qx, qy)
            assert int(st["overflow_queries"]) > 0
        except Exception as exc:  # pragma: no cover - surfaced below
            errors.append(exc)

    # catch_warnings mutates process-global state, so worker-thread
    # warnings are recorded here too
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        threads = [threading.Thread(target=serve) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errors
    # every concurrent batch was counted — none lost to a race
    assert _overflow_streaks[id(plan)] == n_threads
    hits = [w for w in rec if issubclass(w.category, CapacityOverflowWarning)]
    assert len(hits) == 1  # exactly one thread crossed the threshold


def test_reset_not_lost_and_rewarn_after_fresh_streak():
    plan = _plan(seed=23)
    qx, qy = _storm(seed=24)
    with pytest.warns(CapacityOverflowWarning):
        for _ in range(PERSISTENT_OVERFLOW_BATCHES):
            execute_with_stats(plan, qx, qy)
    _, _, st = execute_with_stats(plan, *_clean(seed=25))
    assert st["persistent_overflow"] is False
    assert _overflow_streaks[id(plan)] == 0
    # the reset armed a fresh streak: the threshold warns AGAIN
    with pytest.warns(CapacityOverflowWarning):
        for _ in range(PERSISTENT_OVERFLOW_BATCHES):
            _, _, st = execute_with_stats(plan, qx, qy)
    assert st["persistent_overflow"] is True


def test_streaks_independent_across_plans():
    plan_a, plan_b = _plan(seed=26), _plan(seed=27)
    qx, qy = _storm(seed=28)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", CapacityOverflowWarning)
        for _ in range(PERSISTENT_OVERFLOW_BATCHES):
            _, _, st_a = execute_with_stats(plan_a, qx, qy)
        # interleave ONE overflowing batch against plan_b
        _, _, st_b = execute_with_stats(plan_b, qx, qy)
    assert st_a["persistent_overflow"] is True
    assert st_b["persistent_overflow"] is False
    assert _overflow_streaks[id(plan_a)] == PERSISTENT_OVERFLOW_BATCHES
    assert _overflow_streaks[id(plan_b)] == 1


def test_finalize_drops_entry_on_plan_gc():
    plan = _plan(seed=29)
    key = id(plan)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", CapacityOverflowWarning)
        execute_with_stats(plan, *_storm(seed=30))
    assert key in _overflow_streaks
    del plan
    gc.collect()
    assert key not in _overflow_streaks
