"""Plan/execute engine (repro.engine): parity of the jit-compatible grid
execute against the oracle on uniform + clustered data, jit compilation with
no retrace across same-shape query batches, bitwise plan reuse, the
static-capacity overflow fallback, and the unified dispatch for every impl
(dense family, tiled_v2 diagnostics, idw, chunked)."""

import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.aidw import AIDWParams, aidw_interpolate, aidw_reference
from repro.core.grid import build_grid
from repro.core.idw import idw_reference
from repro.engine import build_plan, execute, execute_with_stats
from repro.engine.execute import _execute
from repro.errors import PathologicalGridWarning
from repro.kernels import aidw, idw
from conftest import make_points

RTOL, ATOL = 2e-4, 2e-5


def _as_jnp(*arrays):
    return tuple(jnp.asarray(a) for a in arrays)


# ------------------------------------------------------------ grid execute
@pytest.mark.parametrize("clustered", [False, True])
def test_grid_execute_matches_reference(clustered):
    """execute(plan, q) must match the oracle on uniform AND clustered data
    (the acceptance parity: same r_obs -> alpha and z_hat as the eager
    brute-force reference, to kernel tolerance)."""
    dx, dy, dz, qx, qy = make_points(900, 400, seed=21, clustered=clustered)
    p = AIDWParams(k=10, area=1.0)
    z_ref, a_ref = aidw_reference(dx, dy, dz, qx, qy, p, area=1.0)
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    z, a = execute(plan, *_as_jnp(qx, qy))
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=RTOL, atol=ATOL)


def test_grid_execute_matches_wrapper():
    """kernels.ops.aidw(impl='grid') routes through the same plan path —
    results must be bitwise identical to a hand-built plan."""
    dx, dy, dz, qx, qy = make_points(700, 300, seed=22, clustered=True)
    p = AIDWParams(k=10, area=1.0)
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    z1, a1 = execute(plan, *_as_jnp(qx, qy))
    z2, a2 = aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl="grid")
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_grid_execute_jit_no_retrace():
    """The acceptance contract: the grid execute step compiles under jax.jit
    (plan built eagerly, execute traced) and does NOT retrace across query
    batches of the same shape."""
    dx, dy, dz, qx1, qy1 = make_points(600, 173, seed=23)
    _, _, _, qx2, qy2 = make_points(600, 173, seed=24)
    p = AIDWParams(k=10, area=1.0)
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    n0 = execute._cache_size()
    z1, a1 = execute(plan, *_as_jnp(qx1, qy1))
    n1 = execute._cache_size()
    z2, a2 = execute(plan, *_as_jnp(qx2, qy2))
    n2 = execute._cache_size()
    assert n1 == n0 + 1, "first same-shape batch should add exactly one executable"
    assert n2 == n1, "second same-shape batch must hit the jit cache (no retrace)"
    # and the traced results are the real thing: parity vs the eager trace
    z_eager, a_eager, _ = _execute(plan, *_as_jnp(qx2, qy2))
    np.testing.assert_allclose(np.asarray(z2), np.asarray(z_eager), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a2), np.asarray(a_eager), rtol=1e-6)


def test_plan_reuse_bitwise_identical():
    """One plan, two query sets: results must be bitwise identical to
    building a fresh plan per batch (nothing about a plan is batch-coupled)."""
    dx, dy, dz, qx1, qy1 = make_points(800, 256, seed=25, clustered=True)
    _, _, _, qx2, qy2 = make_points(800, 256, seed=26, clustered=True)
    p = AIDWParams(k=10, area=1.0)
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    for qx, qy in ((qx1, qy1), (qx2, qy2)):
        z_reused, a_reused = execute(plan, *_as_jnp(qx, qy))
        fresh = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
        z_fresh, a_fresh = execute(fresh, *_as_jnp(qx, qy))
        np.testing.assert_array_equal(np.asarray(z_reused), np.asarray(z_fresh))
        np.testing.assert_array_equal(np.asarray(a_reused), np.asarray(a_fresh))


def test_grid_fallback_stays_exact_out_of_bbox():
    """Query batches beyond the plan's static candidate capacity (far
    out-of-bbox) must flip the fallback flag and STILL match the oracle —
    the static fast path never silently drops a neighbour."""
    dx, dy, dz, qx, qy = make_points(4096, 80, seed=27, clustered=False)
    qx = (qx * 6.0 - 3.0).astype(np.float32)
    qy = (qy * 6.0 - 3.0).astype(np.float32)
    p = AIDWParams(k=10, area=1.0, r_max=64.0)
    # a dense-batch capacity hint keeps the static rows tight, so the far
    # batch genuinely overflows them
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                      query_occupancy=64.0)
    assert plan.cand_capacity < plan.m
    z_ref, a_ref = aidw_reference(dx, dy, dz, qx, qy, p, area=1.0)
    z, a, stats = execute_with_stats(plan, *_as_jnp(qx, qy))
    assert bool(stats["grid_fallback"]), "far queries should exceed the static capacity"
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=RTOL, atol=ATOL)


def test_grid_fast_path_used_for_dense_batches():
    """In-bbox query batches as dense as the data must fit the plan's static
    capacity (no fallback) — the capacity heuristic is doing its job."""
    dx, dy, dz, qx, qy = make_points(2048, 2048, seed=28, clustered=False)
    p = AIDWParams(k=10, area=1.0)
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    _, _, stats = execute_with_stats(plan, *_as_jnp(qx, qy))
    assert not bool(stats["grid_fallback"])
    assert int(stats["cand_need_max"]) <= plan.cand_capacity


def test_grid_plan_autotunes_block_d():
    """Narrow candidate neighbourhoods must shrink the Phase-1 tile below
    the requested block_d (the ROADMAP autotune), and the padded capacity
    must stay a multiple of it."""
    dx, dy, dz, _, _ = make_points(4096, 1, seed=29, clustered=False)
    p = AIDWParams(k=10, area=1.0)
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid", block_d=4096,
                      query_occupancy=64.0)
    assert plan.cand_block_d < 4096
    assert plan.cand_block_d % 128 == 0
    assert plan.cand_capacity % plan.cand_block_d == 0


def test_grid_plan_rebuilds_pathological_resolution():
    """Strongly clustered data on the default (too fine) resolution must
    trigger the plan-time coarsening rebuild; a user-supplied grid must be
    kept and warned about instead."""
    rng = np.random.default_rng(31)
    a = 0.01 * rng.random((400, 2)).astype(np.float32)
    b = 0.99 + 0.01 * rng.random((400, 2)).astype(np.float32)
    pts = np.concatenate([a, b])
    dz = rng.random(800).astype(np.float32)
    p = AIDWParams(k=10, area=1.0)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # may still warn after max rebuilds
        plan = build_plan(pts[:, 0], pts[:, 1], dz, params=p, area=1.0, impl="grid",
                          target_occupancy=0.25)
    assert plan.grid_rebuilds > 0
    g = build_grid(jnp.asarray(pts[:, 0]), jnp.asarray(pts[:, 1]), jnp.asarray(dz),
                   gx=64, gy=64)
    with pytest.warns(PathologicalGridWarning):
        user_plan = build_plan(pts[:, 0], pts[:, 1], dz, params=p, area=1.0,
                               impl="grid", grid=g)
    assert user_plan.grid is g
    assert user_plan.grid_rebuilds == 0


# ------------------------------------------------------- unified dispatch
@pytest.mark.parametrize("impl", ["naive", "tiled", "fused", "tiled_v2"])
def test_dense_plans_match_reference(impl):
    dx, dy, dz, qx, qy = make_points(512, 200, seed=32, clustered=True)
    p = AIDWParams(k=10, area=1.0)
    z_ref, a_ref = aidw_reference(dx, dy, dz, qx, qy, p, area=1.0)
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl=impl,
                      block_q=64, block_d=128)
    z, a = execute(plan, *_as_jnp(qx, qy))
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=RTOL, atol=ATOL)


def test_tiled_v2_dispatch_and_diagnostic():
    """impl='tiled_v2' flows through aidw() and keeps its merge-fraction
    diagnostic via execute_with_stats; the standalone aidw_v2 is deprecated
    but still functional."""
    from repro.kernels.ops import aidw_v2

    dx, dy, dz, qx, qy = make_points(1000, 256, seed=33, clustered=True)
    p = AIDWParams(k=10, area=1.0)
    z1, a1 = aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl="tiled_v2",
                  block_q=64, block_d=128)
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="tiled_v2",
                      block_q=64, block_d=128)
    z2, a2, stats = execute_with_stats(plan, *_as_jnp(qx, qy))
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))
    assert 0.0 < float(stats["merge_fraction"]) <= 1.0
    with pytest.warns(DeprecationWarning):
        z3, a3, frac = aidw_v2(dx, dy, dz, qx, qy, params=p, area=1.0,
                               block_q=64, block_d=128)
    np.testing.assert_array_equal(np.asarray(z3), np.asarray(z2))
    np.testing.assert_array_equal(np.asarray(frac), np.asarray(stats["merge_fraction"]))


def test_idw_plan_matches_reference():
    dx, dy, dz, qx, qy = make_points(400, 150, seed=34)
    plan = build_plan(dx, dy, dz, impl="idw", idw_alpha=2.0, area=1.0,
                      block_q=64, block_d=128)
    z, alpha = execute(plan, *_as_jnp(qx, qy))
    z_ref = idw_reference(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                          jnp.asarray(qx), jnp.asarray(qy), alpha=2.0)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=RTOL, atol=ATOL)
    np.testing.assert_array_equal(np.asarray(alpha), np.full(150, 2.0, np.float32))
    z_wrapper = idw(dx, dy, dz, qx, qy, alpha=2.0, block_q=64, block_d=128)
    np.testing.assert_array_equal(np.asarray(z), np.asarray(z_wrapper))


@pytest.mark.parametrize("knn", ["brute", "grid"])
def test_chunked_plan_matches_interpolate(knn):
    """aidw_interpolate is a thin wrapper over impl='chunked' plans — a
    hand-built plan must reproduce it bitwise, for both knn modes."""
    dx, dy, dz, qx, qy = make_points(700, 300, seed=35, clustered=True)
    p = AIDWParams(k=10, area=1.0)
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="chunked", knn=knn,
                      q_chunk=128, d_chunk=256)
    z1, a1 = execute(plan, *_as_jnp(qx, qy))
    z2, a2 = aidw_interpolate(dx, dy, dz, qx, qy, p, area=1.0, q_chunk=128,
                              d_chunk=256, knn=knn)
    np.testing.assert_array_equal(np.asarray(z1), np.asarray(z2))
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_chunked_grid_execute_is_jit_compatible():
    """Since the refactor the chunked knn='grid' path also executes under an
    outer jit (the grid is a plan child, the ring search is traced)."""
    dx, dy, dz, qx, qy = make_points(600, 200, seed=36)
    p = AIDWParams(k=10, area=1.0)
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="chunked", knn="grid")
    z, a = jax.jit(lambda pl_, x, y: execute(pl_, x, y))(plan, *_as_jnp(qx, qy))
    z_ref, a_ref = aidw_reference(dx, dy, dz, qx, qy, p, area=1.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------- validation
def test_build_plan_validations():
    dx, dy, dz, qx, qy = make_points(128, 32, seed=37)
    p = AIDWParams(k=10, area=1.0)
    with pytest.raises(ValueError):
        build_plan(dx, dy, dz, params=p, area=1.0, impl="octree")
    with pytest.raises(ValueError):
        build_plan(dx, dy, dz, params=p, area=1.0, impl="grid", layout="aoas")
    with pytest.raises(ValueError):
        g = build_grid(jnp.asarray(dx), jnp.asarray(dy))
        build_plan(dx, dy, dz, params=p, area=1.0, impl="tiled", grid=g)
    with pytest.raises(ValueError):
        build_plan(dx, dy, dz, params=p, area=1.0, impl="chunked", knn="octree")
    with pytest.raises(ValueError):
        build_plan(dx[:5], dy[:5], dz[:5], params=p, area=1.0, impl="tiled")
    with pytest.raises(ValueError):
        build_plan(dx, dy, dz, params=AIDWParams(k=10), impl="tiled")
    # the engine plans "idw"/"chunked" but aidw() must keep rejecting them
    # (they have their own entry points with different semantics)
    for impl in ("idw", "chunked"):
        with pytest.raises(ValueError):
            aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl=impl)
