"""Input hardening (PR 9 satellite): non-finite queries yield NaN results
(instead of flowing through the kernel min-reductions into a silently wrong
finite alpha), finite queries in the same batch are untouched — bitwise —
and build_plan rejects non-finite data up front with a clear ValueError."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core.aidw import AIDWParams
from repro.engine import build_plan, execute

P = AIDWParams(k=5, area=1.0, r_max=64.0)
IMPLS = ["grid", "tiled", "idw", "chunked"]


def _data(m=512, seed=40):
    rng = np.random.default_rng(seed)
    dx = rng.random(m).astype(np.float32)
    dy = rng.random(m).astype(np.float32)
    dz = (np.sin(3 * dx) + dy).astype(np.float32)
    return dx, dy, dz


def _mixed_queries(n=64, seed=41):
    """A batch with NaN and Inf scattered through both coordinates."""
    rng = np.random.default_rng(seed)
    qx = rng.random(n).astype(np.float32)
    qy = rng.random(n).astype(np.float32)
    qx[3], qy[7], qx[11] = np.nan, np.nan, np.inf
    qy[12], qx[20] = -np.inf, np.nan
    bad = ~(np.isfinite(qx) & np.isfinite(qy))
    return qx, qy, bad


@pytest.mark.parametrize("impl", IMPLS)
def test_nonfinite_queries_yield_nan_finite_untouched(impl):
    dx, dy, dz = _data()
    plan = build_plan(dx, dy, dz, params=P, area=1.0, impl=impl)
    qx, qy, bad = _mixed_queries()
    z, a = execute(plan, jnp.asarray(qx), jnp.asarray(qy))
    z, a = np.asarray(z), np.asarray(a)
    assert np.isnan(z[bad]).all() and np.isnan(a[bad]).all()
    assert np.isfinite(z[~bad]).all() and np.isfinite(a[~bad]).all()
    # the finite queries' results are bitwise what the same batch computes
    # with the bad slots replaced by the hardening dummy (compute untouched)
    z_ref, a_ref = execute(plan, jnp.asarray(np.where(bad, 0.0, qx).astype(np.float32)),
                           jnp.asarray(np.where(bad, 0.0, qy).astype(np.float32)))
    np.testing.assert_array_equal(z[~bad], np.asarray(z_ref)[~bad])
    np.testing.assert_array_equal(a[~bad], np.asarray(a_ref)[~bad])


def test_nonfinite_handling_survives_outer_jit():
    dx, dy, dz = _data()
    plan = build_plan(dx, dy, dz, params=P, area=1.0, impl="grid")
    qx, qy, bad = _mixed_queries()

    @jax.jit
    def serve(qx, qy):
        return execute(plan, qx, qy)

    z, _ = serve(jnp.asarray(qx), jnp.asarray(qy))
    z = np.asarray(z)
    assert np.isnan(z[bad]).all() and np.isfinite(z[~bad]).all()


def test_all_nan_batch_is_all_nan():
    dx, dy, dz = _data()
    plan = build_plan(dx, dy, dz, params=P, area=1.0, impl="grid")
    qx = jnp.full((32,), jnp.nan, jnp.float32)
    z, a = execute(plan, qx, qx)
    assert np.isnan(np.asarray(z)).all() and np.isnan(np.asarray(a)).all()


@pytest.mark.parametrize("slot", ["dx", "dy", "dz"])
@pytest.mark.parametrize("value", [np.nan, np.inf])
def test_build_plan_rejects_nonfinite_data(slot, value):
    arrays = dict(zip(("dx", "dy", "dz"), _data()))
    arrays[slot] = arrays[slot].copy()
    arrays[slot][17] = value
    with pytest.raises(ValueError, match=f"non-finite values in {slot}"):
        build_plan(arrays["dx"], arrays["dy"], arrays["dz"],
                   params=P, area=1.0, impl="grid")
    with pytest.raises(ValueError, match="non-finite"):
        build_plan(arrays["dx"], arrays["dy"], arrays["dz"],
                   params=P, area=1.0, impl="tiled")
