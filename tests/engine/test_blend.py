"""Sparsity-skipping Phase 1 + per-block overflow blend (PR 4).

Covers the three layers of the grid-path worst-case fix:
* the per-block blend — queries in blocks that overflow the plan's static
  candidate capacity get their alpha from the exact masked ring search,
  everyone else keeps the kernel result (regression for the ROADMAP m=100K
  seam-overflow batch, scaled down; full-size variant marked slow);
* the scalar-prefetch tile-skipping Phase-1 pipeline vs its dense twin
  (bit-identical results, nonzero skipped_tile_fraction on sparse batches);
* Morton seam splitting of query blocks (layout invariants + a
  deterministic straddle whose overflow the split eliminates);
plus the extended execute_with_stats diagnostics (static dict structure,
no retrace) and the convenience-API plan memoization in kernels.ops.
"""

import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.aidw import AIDWParams, adaptive_alpha, aidw_reference
from repro.core.grid import build_grid, cell_of, grid_r_obs, seam_layout, seam_segment_ids
from repro.engine import build_plan, execute, execute_with_stats
from repro.errors import CapacityOverflowWarning
from repro.kernels import aidw, ops

RTOL, ATOL = 2e-4, 2e-5

STATS_KEYS = {
    "grid_fallback", "cand_need_max", "overflow_blocks", "overflow_queries",
    "overflow_query_mask", "skipped_tile_fraction", "persistent_overflow",
}


def _uniform(m, seed):
    rng = np.random.default_rng(seed)
    return (rng.random(m).astype(np.float32), rng.random(m).astype(np.float32),
            rng.random(m).astype(np.float32))


# --------------------------------------------------- overflow blend (tentpole)
def test_seam_overflow_blend_regression():
    """Scaled-down deterministic repro of the ROADMAP m=100K seam-overflow
    batch: one Morton block straddles the grid's centre seams (a full-bbox
    diagonal), its rectangle blows past the static capacity — the blend must
    ring-search exactly those queries (bitwise-equal alpha to the full ring
    search) while the rest of the batch keeps the kernel fast path
    (overflow_blocks > 0 but grid_fallback=False: no whole-batch fallback)."""
    m = 4096
    dx, dy, dz = _uniform(m, 42)
    p = AIDWParams(k=10, area=1.0)
    rng = np.random.default_rng(42)
    qa = (0.05 + 0.03 * rng.random((256, 2))).astype(np.float32)  # tile-local
    t = np.linspace(0.02, 0.98, 256).astype(np.float32)           # seam diagonal
    qx = jnp.asarray(np.concatenate([qa[:, 0], t]))
    qy = jnp.asarray(np.concatenate([qa[:, 1], t]))

    # seam_level=0 keeps the straddling block intact so the blend (not the
    # splitter) is what's under test; the tight capacity makes it overflow
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                      query_occupancy=64.0, seam_level=0)
    z, a, stats = execute_with_stats(plan, qx, qy)

    assert int(stats["overflow_blocks"]) > 0
    assert int(stats["overflow_queries"]) > 0
    assert not bool(stats["grid_fallback"]), "blend must not drop the whole batch"
    mask = np.asarray(stats["overflow_query_mask"])
    assert mask.sum() == int(stats["overflow_queries"])

    # blend exactness invariant: ring-search alpha where overflowed (bitwise
    # — it IS the masked ring search), kernel alpha (same candidates, oracle
    # tolerance) everywhere else
    a_ring = adaptive_alpha(grid_r_obs(plan.grid, qx, qy, p.k), m, 1.0, p)
    np.testing.assert_array_equal(np.asarray(a)[mask], np.asarray(a_ring)[mask])
    z_ref, a_ref = aidw_reference(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                                  qx, qy, p, area=1.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=RTOL, atol=ATOL)


@pytest.mark.slow
def test_seam_overflow_blend_full_size():
    """The actual ROADMAP scenario: m=100K uniform, one full-bbox batch of
    8192 queries.  Unsplit (seam_level=0) it overflows; the blend keeps it
    exact without a whole-batch fallback, and the auto seam split reduces
    the overflow."""
    m = 100_000
    dx, dy, dz = _uniform(m, 0)
    p = AIDWParams(k=10, area=1.0)
    rng = np.random.default_rng(1)
    qx = jnp.asarray(rng.random(8192).astype(np.float32))
    qy = jnp.asarray(rng.random(8192).astype(np.float32))

    plan0 = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid", seam_level=0)
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    assert plan.seam_level > 0, "auto seam split should engage at this scale"
    _, a0, stats0 = execute_with_stats(plan0, qx, qy)
    _, a1, stats1 = execute_with_stats(plan, qx, qy)
    assert int(stats0["overflow_queries"]) > 0, "the ROADMAP cliff should reproduce"
    assert not bool(stats0["grid_fallback"])
    assert int(stats1["overflow_queries"]) < int(stats0["overflow_queries"])
    a_ring = adaptive_alpha(grid_r_obs(plan.grid, qx, qy, p.k), m, 1.0, p)
    for a in (a0, a1):
        np.testing.assert_allclose(np.asarray(a), np.asarray(a_ring), rtol=RTOL, atol=ATOL)


def test_out_of_bbox_batch_all_overflow_is_fallback():
    """When EVERY query lands in an overflowing block the batch degrades to
    ring-search speed — grid_fallback reports it, and it is still exact."""
    dx, dy, dz = _uniform(4096, 7)
    p = AIDWParams(k=10, area=1.0, r_max=64.0)
    rng = np.random.default_rng(8)
    qx = jnp.asarray((rng.random(80) * 6 - 3).astype(np.float32))
    qy = jnp.asarray((rng.random(80) * 6 - 3).astype(np.float32))
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                      query_occupancy=64.0)
    z, a, stats = execute_with_stats(plan, qx, qy)
    assert bool(stats["grid_fallback"])
    assert int(stats["overflow_queries"]) == 80
    z_ref, a_ref = aidw_reference(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                                  qx, qy, p, area=1.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=RTOL, atol=ATOL)


# ------------------------------------------------ prefetch-skip Phase-1 pipeline
def test_prefetch_and_dense_pipelines_bitwise_equal():
    """The tile-skipping pipeline merges exactly the candidates the dense
    walk merges (the skipped tiles are all-sentinel), so z and alpha must be
    bitwise identical — on a sparse tile-local batch where the skip fraction
    is large, and on a full-bbox batch."""
    m = 20000
    dx, dy, dz = _uniform(m, 3)
    p = AIDWParams(k=10, area=1.0)
    rng = np.random.default_rng(4)
    corner = (0.05 + 0.1 * rng.random((256, 2))).astype(np.float32)
    plans = {pipe: build_plan(dx, dy, dz, params=p, area=1.0, impl="grid", pipeline=pipe)
             for pipe in ("prefetch", "dense")}
    qx, qy = jnp.asarray(corner[:, 0]), jnp.asarray(corner[:, 1])
    z_p, a_p, stats = execute_with_stats(plans["prefetch"], qx, qy)
    z_d, a_d, stats_d = execute_with_stats(plans["dense"], qx, qy)
    np.testing.assert_array_equal(np.asarray(z_p), np.asarray(z_d))
    np.testing.assert_array_equal(np.asarray(a_p), np.asarray(a_d))
    assert float(stats["skipped_tile_fraction"]) > 0.5, "tile-local batch should skip most tiles"
    # the diagnostic reports what the launch *would* skip for dense too
    assert float(stats_d["skipped_tile_fraction"]) == float(stats["skipped_tile_fraction"])


def test_build_plan_rejects_bad_pipeline_and_seam_level():
    dx, dy, dz = _uniform(256, 9)
    p = AIDWParams(k=10, area=1.0)
    with pytest.raises(ValueError):
        build_plan(dx, dy, dz, params=p, area=1.0, impl="grid", pipeline="magic")
    with pytest.raises(ValueError):
        build_plan(dx, dy, dz, params=p, area=1.0, impl="grid", seam_level=-1)


# ---------------------------------------------------------- Morton seam split
def test_seam_layout_invariants():
    """src/dest maps: every sorted query owns exactly one slot
    (src[dest[i]] == i), blocks never straddle segment boundaries, and pad
    slots repeat a query of their own segment."""
    block_q = 4
    seg = jnp.asarray([0, 0, 0, 0, 0, 2, 2, 3, 3, 3, 3, 3], jnp.int32)  # nondecreasing
    n_tot = seg.shape[0]
    n_segments = 4
    n_slots = n_tot + n_segments * block_q
    src, dest = seam_layout(seg, n_segments, block_q, n_slots)
    src, dest = np.asarray(src), np.asarray(dest)
    np.testing.assert_array_equal(src[dest], np.arange(n_tot))
    seg_np = np.asarray(seg)
    slot_seg = seg_np[src]  # segment of the query each slot holds
    for b in range(n_slots // block_q):
        blk = slot_seg[b * block_q:(b + 1) * block_q]
        assert len(set(blk.tolist())) == 1, f"block {b} straddles segments: {blk}"


def test_seam_segment_ids_monotone_along_morton():
    """Segment ids are the top Morton bits: nondecreasing along any
    Morton-sorted cell order, constant at level 0."""
    from repro.core.grid import morton_ids

    dx, dy, dz = _uniform(2048, 11)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz))
    rng = np.random.default_rng(12)
    qx = jnp.asarray(rng.random(500).astype(np.float32))
    qy = jnp.asarray(rng.random(500).astype(np.float32))
    cx, cy = cell_of(g, qx, qy)
    order = np.asarray(jnp.argsort(morton_ids(cx, cy)))
    assert int(jnp.max(seam_segment_ids(g, cx, cy, 0))) == 0
    for level in (1, 2):
        seg = np.asarray(seam_segment_ids(g, cx, cy, level))[order]
        assert (np.diff(seg) >= 0).all()
        assert seg.max() < 4 ** level


def test_seam_split_eliminates_straddle_overflow():
    """A deterministic Morton-boundary straddle (queries at the END of
    quadrant 0's Z-curve next to queries at the START of quadrant 1's): one
    block with a half-grid rectangle that overflows the capacity.  Splitting
    at the seam must eliminate the overflow entirely, with identical
    results."""
    m = 16384
    dx, dy, dz = _uniform(m, 5)
    p = AIDWParams(k=10, area=1.0)
    rng = np.random.default_rng(5)
    g = 32  # default resolution for m=16384 at ~16/cell
    fill = (0.2 + 0.1 * rng.random((192, 2))).astype(np.float32)
    qa = ((np.array([g / 2 - 0.5, g / 2 - 0.5]) + 0.02 * rng.random((32, 2))) / g).astype(np.float32)
    qb = ((np.array([g / 2 + 0.5, 0.5]) + 0.02 * rng.random((32, 2))) / g).astype(np.float32)
    q = np.concatenate([fill, qa, qb])
    qx, qy = jnp.asarray(q[:, 0]), jnp.asarray(q[:, 1])

    outs = {}
    for sl in (0, 1):
        plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                          block_q=64, query_occupancy=1024.0, seam_level=sl)
        assert plan.grid.gx == g
        outs[sl] = execute_with_stats(plan, qx, qy)
    assert int(outs[0][2]["overflow_blocks"]) > 0, "the straddle should overflow unsplit"
    assert int(outs[1][2]["overflow_queries"]) == 0, "the seam split should eliminate it"
    np.testing.assert_allclose(np.asarray(outs[0][0]), np.asarray(outs[1][0]),
                               rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(outs[0][1]), np.asarray(outs[1][1]),
                               rtol=RTOL, atol=ATOL)


# ------------------------------------------------------- stats + jit identity
def test_stats_structure_static_per_plan():
    """The extended grid diagnostics keep a static dict structure: two
    same-shape batches against one plan hit the same executable (no
    retrace), and the keys are exactly the documented set."""
    dx, dy, dz = _uniform(2048, 13)
    p = AIDWParams(k=10, area=1.0)
    rng = np.random.default_rng(14)
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    qs = [(jnp.asarray(rng.random(300).astype(np.float32)),
           jnp.asarray(rng.random(300).astype(np.float32))) for _ in range(2)]
    n0 = execute_with_stats._cache_size()
    _, _, stats1 = execute_with_stats(plan, *qs[0])
    n1 = execute_with_stats._cache_size()
    _, _, stats2 = execute_with_stats(plan, *qs[1])
    n2 = execute_with_stats._cache_size()
    assert n1 == n0 + 1 and n2 == n1, "stats dict must not retrace across batches"
    assert set(stats1) == set(stats2) == STATS_KEYS
    assert stats1["overflow_query_mask"].shape == (300,)
    assert 0.0 <= float(stats1["skipped_tile_fraction"]) <= 1.0


def test_persistent_overflow_counter_and_warning():
    """ROADMAP capacity-model regression: a deterministic sparse batch whose
    overflow_queries persists across repeated execute_with_stats calls must
    raise the persistent_overflow flag (and a one-shot RuntimeWarning
    suggesting a re-plan) once the streak reaches the threshold — the hook
    the future per-batch capacity re-estimator builds on.  A clean batch
    resets the streak; a fresh plan starts from zero."""
    from repro.engine.execute import PERSISTENT_OVERFLOW_BATCHES

    dx, dy, dz = _uniform(4096, 19)
    p = AIDWParams(k=10, area=1.0, r_max=64.0)
    rng = np.random.default_rng(20)
    # deterministic sparse out-of-bbox batch: overflows the tight capacity
    qx = jnp.asarray((rng.random(64) * 6 - 3).astype(np.float32))
    qy = jnp.asarray((rng.random(64) * 6 - 3).astype(np.float32))
    # clean batch: tile-local (compact block rectangle fits the capacity)
    qcx = jnp.asarray((0.4 + 0.05 * rng.random(64)).astype(np.float32))
    qcy = jnp.asarray((0.4 + 0.05 * rng.random(64)).astype(np.float32))
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                      query_occupancy=64.0)

    assert PERSISTENT_OVERFLOW_BATCHES == 3
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # the first two batches must NOT warn
        for _ in range(PERSISTENT_OVERFLOW_BATCHES - 1):
            _, _, stats = execute_with_stats(plan, qx, qy)
            assert int(stats["overflow_queries"]) > 0
            assert stats["persistent_overflow"] is False
    with pytest.warns(CapacityOverflowWarning):
        _, _, stats = execute_with_stats(plan, qx, qy)
    assert stats["persistent_overflow"] is True
    # further overflowing batches keep the flag without re-warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        _, _, stats = execute_with_stats(plan, qx, qy)
    assert stats["persistent_overflow"] is True
    # one clean batch resets the streak
    _, _, stats = execute_with_stats(plan, qcx, qcy)
    assert int(stats["overflow_queries"]) == 0
    assert stats["persistent_overflow"] is False
    # plan identity scopes the streak: a fresh plan starts clean
    plan2 = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                       query_occupancy=64.0)
    _, _, stats = execute_with_stats(plan2, qx, qy)
    assert stats["persistent_overflow"] is False


def test_execute_with_stats_composes_under_outer_jit():
    """Wrapping execute_with_stats in an outer jax.jit must keep working
    (pre-tracking behaviour): the host-side streak bookkeeping is skipped
    under a trace — the stats are tracers there — instead of raising."""
    import jax

    dx, dy, dz = _uniform(1024, 21)
    p = AIDWParams(k=10, area=1.0)
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    rng = np.random.default_rng(22)
    qx = jnp.asarray(rng.random(100).astype(np.float32))
    qy = jnp.asarray(rng.random(100).astype(np.float32))
    z_j, a_j, stats_j = jax.jit(
        lambda x, y: execute_with_stats(plan, x, y))(qx, qy)
    assert "persistent_overflow" not in stats_j
    z_e, a_e, stats_e = execute_with_stats(plan, qx, qy)
    assert "persistent_overflow" in stats_e
    np.testing.assert_array_equal(np.asarray(z_j), np.asarray(z_e))
    np.testing.assert_array_equal(np.asarray(a_j), np.asarray(a_e))


# --------------------------------------------------- convenience plan memoization
def test_ops_plan_cache_reuses_plan():
    """Two aidw() calls on the same data arrays must build ONE plan (weak-ref
    cache keyed on array ids + statics); new arrays — even equal ones — miss."""
    dx, dy, dz = _uniform(600, 15)
    rng = np.random.default_rng(16)
    qx, qy = rng.random(100).astype(np.float32), rng.random(100).astype(np.float32)
    qx2, qy2 = rng.random(100).astype(np.float32), rng.random(100).astype(np.float32)
    p = AIDWParams(k=10, area=1.0)
    ops.plan_cache_clear()
    z1, a1 = aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl="grid")
    assert ops._plan_cache_counters == {"hits": 0, "misses": 1}
    (entry,) = ops._PLAN_CACHE.values()
    plan_first = entry[1]
    z2, a2 = aidw(dx, dy, dz, qx2, qy2, params=p, area=1.0, impl="grid")
    assert ops._plan_cache_counters == {"hits": 1, "misses": 1}
    (entry,) = ops._PLAN_CACHE.values()
    assert entry[1] is plan_first, "second call must reuse the same plan object"
    # a same-shape second batch through the cached plan matches a fresh plan
    fresh = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    z_ref, a_ref = execute(fresh, jnp.asarray(qx2), jnp.asarray(qy2))
    np.testing.assert_array_equal(np.asarray(z2), np.asarray(z_ref))
    np.testing.assert_array_equal(np.asarray(a2), np.asarray(a_ref))
    # different array objects (equal contents) are a different dataset identity
    z3, _ = aidw(dx.copy(), dy.copy(), dz.copy(), qx, qy, params=p, area=1.0, impl="grid")
    assert ops._plan_cache_counters["misses"] == 2
    np.testing.assert_array_equal(np.asarray(z3), np.asarray(z1))
    # dropping the data arrays evicts their entry (no pinned dataset copies)
    n_before = len(ops._PLAN_CACHE)
    del dx, dy, dz, entry, plan_first
    import gc

    gc.collect()
    assert len(ops._PLAN_CACHE) < n_before
    ops.plan_cache_clear()


def test_ops_plan_cache_distinguishes_config():
    dx, dy, dz = _uniform(600, 17)
    rng = np.random.default_rng(18)
    qx, qy = rng.random(64).astype(np.float32), rng.random(64).astype(np.float32)
    p = AIDWParams(k=10, area=1.0)
    ops.plan_cache_clear()
    aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl="grid")
    aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl="tiled", block_q=64, block_d=128)
    assert ops._plan_cache_counters == {"hits": 0, "misses": 2}
    assert len(ops._PLAN_CACHE) == 2
    ops.plan_cache_clear()
