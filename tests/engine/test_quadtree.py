"""Quadtree far-field Phase 2 (build_plan(phase2="quadtree"), DESIGN.md §8).

What this file enforces, beyond the single-level contract of
test_farfield.py:

* the measured error stays within the plan's proved dipole bound on
  uniform / clustered / seam / out-of-bbox query distributions — and the
  bound itself is <= 1e-3 at the plan-chosen sub-cell-clustered
  configuration (the "finally proves rtol=1e-3" acceptance);
* there exist configurations (z varying INSIDE tight spatial clusters)
  where the single-level model cannot prove 1e-3 at the same radius but
  the dipole model does — the reason the quadtree arm exists;
* every quadtree level re-aggregates EXACTLY (bitwise) to a NumPy
  reduction of the level below, and the per-node dispersion/z-spread
  fields really are upper bounds over the raw points (hypothesis + grid
  sweep);
* the proved bound is monotone non-increasing as the opening ratio
  shrinks;
* near-capacity or level-table overflow routes those queries to the exact
  sweep — bitwise — never to a truncated approximation;
* the stats dict has static structure (no retrace across same-shape
  batches) and carries {cells_per_level, opened_fraction,
  quadtree_rtol_bound}.
"""

import warnings

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.accuracy import farfield_error_report
from repro.core.aidw import AIDWParams
from repro.core.grid import build_grid, quadtree_aggregates, quadtree_level_count
from repro.engine import build_plan, execute, execute_with_stats
from repro.engine.plan import _bound_from_tau, _quadtree_tau_required
from repro.errors import UnprovableRtolWarning

P = AIDWParams(k=10, area=1.0)
DISTRIBUTIONS = ("uniform", "clustered", "seam", "out_of_bbox")


def _field(x, y):
    return (np.sin(6 * x) * np.cos(6 * y) + 2.0).astype(x.dtype)


def _tight_data(seed, dtype=np.float32, gx=12, m=4000, sigma=1e-4,
                z_noise=0.0):
    """Per-cell clusters far below the cell scale: the opening ratio of
    every level-0 cell fits tau_req, so the dipole bound PROVES rtol=1e-3.
    ``z_noise`` adds z variation INSIDE each cluster — harmless to the
    dipole model (its z budget is second-order with an |z|-scale
    coefficient) but first-order poison for the single-level model."""
    rng = np.random.default_rng(seed)
    centers = (np.stack(np.meshgrid(np.arange(gx), np.arange(gx)), -1)
               .reshape(-1, 2) + 0.5) / gx
    pts = centers[rng.integers(0, gx * gx, m)] + rng.normal(0, sigma, (m, 2))
    pts = np.clip(pts, 0.0, 1.0).astype(dtype)
    dx, dy = pts[:, 0], pts[:, 1]
    dz = _field(dx, dy) + (z_noise * rng.standard_normal(m)).astype(dtype)
    return dx, dy, dz.astype(dtype)


def _queries(dist, nq, seed, dtype=np.float32):
    rng = np.random.default_rng(seed)
    if dist == "uniform":
        q = rng.random((nq, 2))
    elif dist == "clustered":
        q = 0.35 + 0.12 * rng.random((nq, 2))
    elif dist == "seam":
        t = np.linspace(0.02, 0.98, nq)
        q = np.stack([t, t], 1) + rng.normal(0, 0.01, (nq, 2))
    elif dist == "out_of_bbox":
        q = rng.random((nq, 2)) * 6.0 - 3.0
    else:  # pragma: no cover
        raise ValueError(dist)
    return q.astype(dtype)[:, 0], q.astype(dtype)[:, 1]


def _quadtree_plan(dx, dy, dz, *, gx=12, block_q=64, **kw):
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                   gx=gx, gy=gx)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        return build_plan(dx, dy, dz, params=P, area=1.0, impl="grid",
                          grid=g, phase2="quadtree", block_q=block_q, **kw)


# ------------------------------------------------ error budget (tentpole)
@pytest.mark.parametrize("dist", DISTRIBUTIONS)
def test_measured_error_within_proved_bound(dist):
    """Acceptance: measured max relative error <= the proved dipole bound
    on all four query distributions — AND the bound itself proves the
    default rtol=1e-3 at this plan-chosen configuration (the single-level
    arm's provable floor at profitable radii is ~0.25, see DESIGN.md §7)."""
    dx, dy, dz = _tight_data(seed=10)
    qx, qy = _queries(dist, 220, seed=11)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # a provable config must not warn
        plan = _quadtree_plan(dx, dy, dz)
    assert plan.farfield_bound <= 1e-3, "the dipole bound must prove rtol=1e-3"
    assert len(plan.qt_levels) == quadtree_level_count(12, 12)
    rep = farfield_error_report(plan, jnp.asarray(qx), jnp.asarray(qy))
    assert rep["phase2"] == "quadtree"
    assert rep["bound"] == plan.farfield_bound
    assert rep["within_bound"], rep


def test_quadtree_proves_where_single_level_cannot():
    """The reason the dipole term exists: z varying inside tight spatial
    clusters costs the single-level model a first-order term (eta * g) that
    blocks rtol=1e-3, while the dipole model stays second-order and proves
    it at the same radius."""
    dx, dy, dz = _tight_data(seed=20, z_noise=0.5)
    plan_q = _quadtree_plan(dx, dy, dz)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                   gx=12, gy=12)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        plan_f = build_plan(dx, dy, dz, params=P, area=1.0, impl="grid",
                            grid=g, phase2="farfield", block_q=64,
                            farfield_radius=plan_q.farfield_radius)
    assert plan_q.farfield_bound <= 1e-3
    assert plan_f.farfield_bound > 1e-3, (
        "single-level bound unexpectedly proves 1e-3 here — the first-order "
        "z term should block it"
    )
    qx, qy = _queries("uniform", 200, seed=21)
    rep = farfield_error_report(plan_q, jnp.asarray(qx), jnp.asarray(qy))
    assert rep["within_bound"], rep


def test_measured_error_within_bound_f64():
    import jax

    with jax.experimental.enable_x64():
        dx, dy, dz = _tight_data(seed=12, dtype=np.float64)
        qx, qy = _queries("out_of_bbox", 150, seed=13, dtype=np.float64)
        plan = _quadtree_plan(dx, dy, dz)
        assert plan.farfield_bound <= 1e-3
        rep = farfield_error_report(plan, jnp.asarray(qx), jnp.asarray(qy))
        assert rep["within_bound"], rep
        assert rep["max_rel_err"] <= plan.farfield_bound + 1e-12


def test_unprovable_config_warns_and_stays_within_honest_bound():
    """Coarse data (dispersion ~ the cell size) cannot meet tau_req: the
    plan warns, reports the honest (larger) bound, and the measured error
    still honours it."""
    rng = np.random.default_rng(30)
    dx = rng.random(3000).astype(np.float32)
    dy = rng.random(3000).astype(np.float32)
    dz = _field(dx, dy)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                   gx=12, gy=12)
    with pytest.warns(UnprovableRtolWarning):
        plan = build_plan(dx, dy, dz, params=P, area=1.0, impl="grid",
                          grid=g, phase2="quadtree", block_q=64)
    assert plan.farfield_bound > 1e-3
    qx, qy = _queries("uniform", 200, seed=31)
    rep = farfield_error_report(plan, jnp.asarray(qx), jnp.asarray(qy))
    assert rep["within_bound"], rep


# ------------------------------------------- level re-aggregation (bitwise)
def _assert_levels_consistent(g):
    """Bitwise: combining level l's 2x2 children with the documented exact
    reductions reproduces level l+1's count/z-sum/centroid/moment arrays;
    conservative: per-node e/zd really bound the raw points."""
    qt = quadtree_aggregates(g)
    assert len(qt) == quadtree_level_count(g.gx, g.gy)
    for a, b in zip(qt, qt[1:]):
        def img(arr, lv=a):
            return np.asarray(arr).reshape(lv.ny, lv.nx)

        def pad(x, fill=0.0):
            return np.pad(x, ((0, a.ny % 2), (0, a.nx % 2)),
                          constant_values=fill)

        ch = [(pad(img(a.count))[dy::2, dx::2], pad(img(a.z_sum))[dy::2, dx::2],
               pad(img(a.cent_x))[dy::2, dx::2], pad(img(a.cent_y))[dy::2, dx::2],
               pad(img(a.mx))[dy::2, dx::2], pad(img(a.my))[dy::2, dx::2])
              for dy, dx in ((0, 0), (0, 1), (1, 0), (1, 1))]
        cnt = ((ch[0][0] + ch[1][0]) + ch[2][0]) + ch[3][0]
        zs = ((ch[0][1] + ch[1][1]) + ch[2][1]) + ch[3][1]
        np.testing.assert_array_equal(np.asarray(b.count).reshape(b.ny, b.nx), cnt)
        np.testing.assert_array_equal(np.asarray(b.z_sum).reshape(b.ny, b.nx), zs)
        denom = np.maximum(cnt, np.asarray(1.0, cnt.dtype))
        wx = ((ch[0][0] * ch[0][2] + ch[1][0] * ch[1][2])
              + ch[2][0] * ch[2][2]) + ch[3][0] * ch[3][2]
        wy = ((ch[0][0] * ch[0][3] + ch[1][0] * ch[1][3])
              + ch[2][0] * ch[2][3]) + ch[3][0] * ch[3][3]
        bx = np.asarray(b.cent_x).reshape(b.ny, b.nx)
        by = np.asarray(b.cent_y).reshape(b.ny, b.nx)
        nonempty = cnt > 0
        np.testing.assert_array_equal(np.where(nonempty, wx / denom, bx), bx)
        np.testing.assert_array_equal(np.where(nonempty, wy / denom, by), by)
        mx = sum(c[4] + c[1] * (c[2] - bx) for c in ch)
        my = sum(c[5] + c[1] * (c[3] - by) for c in ch)
        np.testing.assert_array_equal(np.asarray(b.mx).reshape(b.ny, b.nx), mx)
        np.testing.assert_array_equal(np.asarray(b.my).reshape(b.ny, b.nx), my)

    # conservative invariants against the raw CSR layout, every level
    counts = np.asarray(g.counts).reshape(-1)
    cell_x, cell_y, cell_z = (np.asarray(g.cell_x), np.asarray(g.cell_y),
                              np.asarray(g.cell_z))
    for level in qt:
        for c in range(g.n_cells):
            k = int(counts[c])
            if k == 0:
                continue
            iy, ix = divmod(c, g.gx)
            nid = (iy // level.step) * level.nx + (ix // level.step)
            d = np.sqrt(
                (cell_x[c, :k].astype(np.float64) - float(level.cent_x[nid])) ** 2
                + (cell_y[c, :k].astype(np.float64) - float(level.cent_y[nid])) ** 2
            )
            assert (d <= float(level.e[nid]) + 1e-5).all()
            zbar = float(level.z_sum[nid]) / float(level.count[nid])
            zdev = np.abs(cell_z[c, :k].astype(np.float64) - zbar)
            assert (zdev <= float(level.zd[nid]) + 1e-4).all()


@pytest.mark.parametrize("gx", [3, 5, 12])
def test_level_reaggregation_bitwise(gx):
    dx, dy, dz = _tight_data(seed=40 + gx, gx=max(gx, 2), m=500, sigma=0.01)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                   gx=gx, gy=gx)
    _assert_levels_consistent(g)


def test_level_reaggregation_property():
    """Arbitrary point sets x tiny/odd grid resolutions.  Hypothesis is a CI
    dependency; without it this falls back to a fixed adversarial battery
    (identical points, two-corner, collinear, random) rather than skipping,
    so the tier-1 skip count stays flat and the CI skip-count guard keeps
    the real sweep honest."""
    def check(pts, gres):
        pts = np.asarray(pts, np.float32)
        g = build_grid(jnp.asarray(pts[:, 0]), jnp.asarray(pts[:, 1]),
                       jnp.asarray(pts[:, 2]), gx=gres, gy=gres)
        _assert_levels_consistent(g)

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        rng = np.random.default_rng(0)
        cases = [
            np.full((12, 3), 0.5, np.float32),
            np.array([[0.0, 0.0, -3.0]] * 6 + [[1.0, 1.0, 3.0]] * 6,
                     dtype=np.float32),
            np.column_stack([np.linspace(0, 1, 20), np.zeros(20),
                             np.linspace(-3, 3, 20)]).astype(np.float32),
            rng.random((60, 3)).astype(np.float32),
        ]
        for gres in (2, 3, 6, 9):
            for pts in cases:
                check(pts, gres)
        return

    coord = st.floats(0.0, 1.0, allow_nan=False, width=32)
    zval = st.floats(-3.0, 3.0, allow_nan=False, width=32)

    @settings(deadline=None, max_examples=15)
    @given(
        pts=st.lists(st.tuples(coord, coord, zval), min_size=12, max_size=60),
        gres=st.sampled_from([2, 3, 6, 9]),
    )
    def run(pts, gres):
        check(pts, gres)

    run()


# ----------------------------------------------------------- bound model
def test_dipole_bound_monotone_in_tau():
    """The proved bound is monotone non-increasing as the opening ratio
    shrinks (the property the plan's level-selection relies on), sits
    strictly below the single-level bound wherever z varies in-cell, and
    the tau_req solver inverts it."""
    taus = np.linspace(0.3, 1e-4, 60)
    bounds = [_bound_from_tau(float(t), 4.0, dipole=True) for t in taus]
    assert all(b1 >= b2 for b1, b2 in zip(bounds, bounds[1:]))
    assert _bound_from_tau(0.0, 4.0, dipole=True) == 0.0
    assert _bound_from_tau(1.0, 4.0, dipole=True) == np.inf
    # second-order vs first-order: strictly better when g > 0
    for t in (0.01, 0.05, 0.1):
        assert (_bound_from_tau(t, 4.0, dipole=True)
                < _bound_from_tau(t, 4.0, g=0.5))
    for rtol in (1e-2, 1e-3, 1e-4):
        tau = _quadtree_tau_required(4.0, rtol)
        assert _bound_from_tau(tau, 4.0, dipole=True) <= rtol
        assert _bound_from_tau(tau * 1.1, 4.0, dipole=True) > rtol


def test_dipole_bound_monotone_property():
    """Same local-fallback policy as test_level_reaggregation_property."""
    def check(tau, shrink, a):
        assert (_bound_from_tau(tau * shrink, a, dipole=True)
                <= _bound_from_tau(tau, a, dipole=True))

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        rng = np.random.default_rng(1)
        for _ in range(500):
            check(10.0 ** rng.uniform(-6, np.log10(0.9)),
                  rng.uniform(0.1, 1.0),
                  float(rng.choice([2.0, 3.0, 4.0, 5.0])))
        return

    @settings(deadline=None, max_examples=50)
    @given(
        tau=st.floats(1e-6, 0.9, allow_nan=False),
        shrink=st.floats(0.1, 1.0, allow_nan=False),
        a=st.sampled_from([2.0, 3.0, 4.0, 5.0]),
    )
    def run(tau, shrink, a):
        check(tau, shrink, a)

    run()


# ------------------------------------------------------- overflow fallback
def test_overflow_falls_back_to_exact_bitwise():
    """Out-of-bbox batches overflowing the near capacity take the per-block
    masked exact sweep: bitwise the exact plan's answer, and the overflow
    is reported per query."""
    rng = np.random.default_rng(14)
    dx = rng.random(4096).astype(np.float32)
    dy = rng.random(4096).astype(np.float32)
    dz = _field(dx, dy)
    p = AIDWParams(k=10, area=1.0, r_max=64.0)
    qx = jnp.asarray((rng.random(96) * 6 - 3).astype(np.float32))
    qy = jnp.asarray((rng.random(96) * 6 - 3).astype(np.float32))
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        plan_qt = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                             phase2="quadtree", farfield_radius=1,
                             query_occupancy=64.0)
        plan_ex = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                             query_occupancy=64.0)
    assert plan_qt.p2_capacity < plan_qt.m
    z_qt, a_qt, stats = execute_with_stats(plan_qt, qx, qy)
    z_ex, a_ex = execute(plan_ex, qx, qy)
    assert int(stats["p2_overflow_queries"]) == 96
    np.testing.assert_array_equal(np.asarray(z_qt), np.asarray(z_ex))
    np.testing.assert_array_equal(np.asarray(a_qt), np.asarray(a_ex))


# -------------------------------------------------- stats / no-retrace
def test_quadtree_stats_static_and_no_retrace():
    dx, dy, dz = _tight_data(seed=15)
    plan = _quadtree_plan(dx, dy, dz)
    rng = np.random.default_rng(16)
    qs = [(jnp.asarray(rng.random(200).astype(np.float32)),
           jnp.asarray(rng.random(200).astype(np.float32))) for _ in range(2)]
    n0 = execute_with_stats._cache_size()
    _, _, s1 = execute_with_stats(plan, *qs[0])
    n1 = execute_with_stats._cache_size()
    _, _, s2 = execute_with_stats(plan, *qs[1])
    n2 = execute_with_stats._cache_size()
    assert n1 == n0 + 1 and n2 == n1, "quadtree stats must not retrace"
    assert set(s1) == set(s2)
    assert {"cells_per_level", "opened_fraction", "quadtree_rtol_bound",
            "far_cells_mean", "near_points_mean",
            "p2_overflow_queries"} < set(s1)
    assert s1["cells_per_level"].shape == (len(plan.qt_levels),)
    assert float(s1["far_cells_mean"]) > 0
    assert np.allclose(float(jnp.sum(s1["cells_per_level"])),
                       float(s1["far_cells_mean"]), rtol=1e-5)
    assert 0.0 <= float(s1["opened_fraction"]) <= 1.0
    assert float(s1["quadtree_rtol_bound"]) == np.float32(plan.farfield_bound)


# -------------------------------------------------------------- validations
def test_quadtree_validations():
    dx, dy, dz = _tight_data(seed=7, m=256)
    with pytest.raises(ValueError, match="phase2"):
        build_plan(dx, dy, dz, params=P, area=1.0, impl="grid", phase2="bh")
    with pytest.raises(ValueError, match="quadtree"):
        build_plan(dx, dy, dz, params=P, area=1.0, impl="tiled",
                   phase2="quadtree")
    # exact/farfield plans carry empty quadtree statics
    plan = build_plan(dx, dy, dz, params=P, area=1.0, impl="grid")
    assert plan.qt_levels == () and plan.qt_tau == 0.0
