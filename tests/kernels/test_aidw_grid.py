"""Grid-kNN AIDW paths vs the oracle: the Pallas grid kernel (impl="grid",
interpret mode) and the pure-jnp grid-accelerated interpolate (knn="grid")
must match aidw_reference on uniform AND clustered data — including ragged
shapes, grid reuse, exact hits, and out-of-grid queries."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.aidw import AIDWParams, aidw_interpolate, aidw_reference
from repro.core.grid import build_grid
from repro.kernels import aidw
from conftest import make_points

RTOL, ATOL = 2e-4, 2e-5


def _check_grid_kernel(m, n, k=10, block_q=64, block_d=128, seed=0, clustered=True):
    dx, dy, dz, qx, qy = make_points(m, n, seed=seed, clustered=clustered)
    p = AIDWParams(k=k, area=1.0)
    z_ref, a_ref = aidw_reference(dx, dy, dz, qx, qy, p, area=1.0)
    z, a = aidw(
        dx, dy, dz, qx, qy,
        params=p, area=1.0, impl="grid", block_q=block_q, block_d=block_d,
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("clustered", [False, True])
@pytest.mark.parametrize("m,n", [(512, 256), (500, 203), (130, 77), (1024, 64)])
def test_grid_kernel_shape_sweep(m, n, clustered):
    _check_grid_kernel(m, n, seed=m + n, clustered=clustered)


@pytest.mark.parametrize("k", [1, 4, 10, 16])
def test_grid_kernel_k_sweep(k):
    _check_grid_kernel(300, 100, k=k, seed=k)


@pytest.mark.parametrize("block_q,block_d", [(32, 64), (64, 256), (128, 128)])
def test_grid_kernel_block_sweep(block_q, block_d):
    _check_grid_kernel(700, 300, block_q=block_q, block_d=block_d, seed=block_q)


def test_grid_kernel_exact_hits():
    dx, dy, dz, _, _ = make_points(256, 1, seed=9)
    z, _ = aidw(
        dx, dy, dz, dx[:64], dy[:64],
        params=AIDWParams(k=8, area=1.0), area=1.0, impl="grid",
        block_q=32, block_d=64,
    )
    np.testing.assert_allclose(np.asarray(z), dz[:64], atol=1e-6)


@pytest.mark.parametrize("stretch", [2.0, 6.0])
def test_grid_kernel_queries_outside_data_bbox(stretch):
    """Far out-of-bbox queries (up to [-3, 3]^2 around unit-square data) need
    the overhang-corrected safe_radius — the naive (r+1)*diag bound provably
    drops true neighbours there.  Parity is checked on r_obs (via a fine
    custom grid + non-saturating r_max) so a containment miss is visible in
    alpha, not masked by the fuzzy-membership clamp."""
    dx, dy, dz, qx, qy = make_points(400, 60, seed=12, clustered=True)
    qx = (qx * stretch - stretch / 4).astype(np.float32)
    qy = (qy * stretch - stretch / 4).astype(np.float32)
    p = AIDWParams(k=10, area=1.0, r_max=64.0)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), gx=40, gy=40)
    z_ref, a_ref = aidw_reference(dx, dy, dz, qx, qy, p, area=1.0)
    z, a = aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl="grid", grid=g,
                block_q=32, block_d=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=RTOL, atol=ATOL)


def test_grid_kernel_prebuilt_grid_reuse():
    """A prebuilt grid must give identical results across query batches."""
    dx, dy, dz, qx, qy = make_points(600, 200, seed=13, clustered=True)
    p = AIDWParams(k=10, area=1.0)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz))
    z1, a1 = aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl="grid", grid=g)
    z2, a2 = aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl="grid")
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-6)


def test_grid_kernel_rejects_aoas_layout():
    dx, dy, dz, qx, qy = make_points(128, 32, seed=14)
    with pytest.raises(ValueError):
        aidw(dx, dy, dz, qx, qy, params=AIDWParams(k=10, area=1.0), area=1.0,
             impl="grid", layout="aoas")


def test_grid_kwarg_rejected_for_dense_impls():
    dx, dy, dz, qx, qy = make_points(128, 32, seed=14)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy))
    with pytest.raises(ValueError):
        aidw(dx, dy, dz, qx, qy, params=AIDWParams(k=10, area=1.0), area=1.0,
             impl="tiled", grid=g)


@pytest.mark.parametrize("clustered", [False, True])
def test_interpolate_knn_grid_matches_brute(clustered):
    """aidw_interpolate(knn='grid') == aidw_interpolate(knn='brute'), both
    chunkings, plus grid reuse."""
    dx, dy, dz, qx, qy = make_points(900, 400, seed=15, clustered=clustered)
    p = AIDWParams(k=10, area=1.0)
    zb, ab = aidw_interpolate(dx, dy, dz, qx, qy, p, area=1.0, q_chunk=128, d_chunk=256)
    zg, ag = aidw_interpolate(dx, dy, dz, qx, qy, p, area=1.0, q_chunk=128, d_chunk=256,
                              knn="grid")
    np.testing.assert_allclose(np.asarray(ag), np.asarray(ab), rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(zg), np.asarray(zb), rtol=1e-6, atol=1e-7)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy))
    zg2, _ = aidw_interpolate(dx, dy, dz, qx, qy, p, area=1.0, q_chunk=128, d_chunk=256,
                              knn="grid", grid=g)
    np.testing.assert_allclose(np.asarray(zg2), np.asarray(zg), rtol=1e-6)


def test_interpolate_rejects_unknown_knn():
    dx, dy, dz, qx, qy = make_points(64, 16, seed=16)
    with pytest.raises(ValueError):
        aidw_interpolate(dx, dy, dz, qx, qy, AIDWParams(k=5, area=1.0), area=1.0,
                         knn="octree")
