"""Beyond-paper kernel variants (EXPERIMENTS §Perf-AIDW):
  * tiled_v2 (threshold-skip) — must stay EXACT regardless of skip behaviour;
    its measured merge fraction is the §Perf refutation evidence;
  * binned prefilter — approximate; error must stay within the documented
    envelope and vanish as m grows.
"""

import numpy as np
import pytest

from repro.core.aidw import AIDWParams
from repro.kernels import aidw
from repro.kernels.ops import aidw_v2
from repro.kernels.ref import aidw_ref
from repro.data.spatial import clustered_points, uniform_points


def _setup(m, n=512, seed=1):
    dx, dy, dz = clustered_points(m, seed=seed)
    qx, qy, _ = uniform_points(n, seed=seed + 1)
    p = AIDWParams(k=10, area=1.0)
    z_ref, a_ref = aidw_ref(dx, dy, dz, qx, qy, p, 1.0)
    return dx, dy, dz, qx, qy, p, np.asarray(z_ref), np.asarray(a_ref)


@pytest.mark.parametrize("m", [1000, 4096])
def test_threshold_skip_exact(m):
    dx, dy, dz, qx, qy, p, z_ref, a_ref = _setup(m)
    with pytest.warns(DeprecationWarning):  # standalone entry point deprecated
        z, a, frac = aidw_v2(dx, dy, dz, qx, qy, params=p, area=1.0, block_q=64, block_d=128)
    np.testing.assert_allclose(np.asarray(z), z_ref, rtol=2e-4, atol=2e-5)
    np.testing.assert_allclose(np.asarray(a), a_ref, rtol=2e-4, atol=2e-5)
    assert 0.0 < float(frac) <= 1.0


def test_threshold_skip_merge_fraction_refutation():
    """The §Perf refutation: at (256 x 512) block granularity every tile has
    a candidate for SOME query in the block, so the skip never fires —
    merge fraction stays ~1.  (Kept as a regression guard on the analysis.)"""
    dx, dy, dz, qx, qy, p, _, _ = _setup(16384, n=1024)
    with pytest.warns(DeprecationWarning):  # standalone entry point deprecated
        _, _, frac = aidw_v2(dx, dy, dz, qx, qy, params=p, area=1.0, block_q=256, block_d=512)
    assert float(frac) > 0.95


@pytest.mark.parametrize("m", [32768])
def test_binned_prefilter_error_envelope(m):
    dx, dy, dz, qx, qy, p, z_ref, a_ref = _setup(m, n=1024)
    z, a = aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl="binned")
    rel = np.abs(np.asarray(z) - z_ref) / (np.abs(z_ref) + 1e-9)
    da = np.abs(np.asarray(a) - a_ref)
    assert rel.mean() < 1e-4, rel.mean()
    assert rel.max() < 2e-2, rel.max()
    assert (da > 0.05).mean() < 0.02  # <2% of queries see a visible alpha shift


def test_binned_error_shrinks_with_m():
    errs = []
    for m in (8192, 65536):
        dx, dy, dz, qx, qy, p, z_ref, _ = _setup(m, n=512)
        z, _ = aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl="binned")
        errs.append(float(np.mean(np.abs(np.asarray(z) - z_ref) / (np.abs(z_ref) + 1e-9))))
    assert errs[1] <= errs[0]
