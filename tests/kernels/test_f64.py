"""Double-precision validation (paper §4.2) — run in a subprocess so
JAX_ENABLE_X64 does not leak into the rest of the suite.

On the paper's GT 730M, f64 ran at 1/24 rate; on TPU there is no native f64
at all (the target would emulate).  Numerical correctness of the f64 kernels
is still validated here in interpret mode, and the Kahan-f32 variant is
checked to close most of the f32->f64 accuracy gap (DESIGN.md §2).
"""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import numpy as np, jax.numpy as jnp
from repro.core.aidw import AIDWParams
from repro.core.accuracy import aidw_interpolate_kahan, relative_rmse
from repro.core.aidw import aidw_interpolate
from repro.kernels import aidw
from repro.kernels.ref import aidw_ref

assert jnp.zeros(()).dtype == jnp.float64 or True
rng = np.random.default_rng(5)
m, n = 600, 250
centers = rng.random((10, 2))
pts = np.clip(centers[rng.integers(0, 10, m)] + rng.normal(0, .02, (m, 2)), 0, 1)
dx64, dy64 = pts[:, 0], pts[:, 1]
dz64 = np.sin(6 * dx64) * np.cos(6 * dy64) + 2.0
qx64, qy64 = rng.random(n), rng.random(n)
p = AIDWParams(k=10, area=1.0)

# f64 oracle
z64, a64 = aidw_ref(jnp.float64(dx64), jnp.float64(dy64), jnp.float64(dz64),
                    jnp.float64(qx64), jnp.float64(qy64), p, 1.0)
z64 = np.asarray(z64)

# f64 kernels (interpret mode) must match the f64 oracle tightly
for impl, layout in (("tiled", "soa"), ("naive", "soa"), ("fused", "soa"), ("tiled", "aoas")):
    z, a = aidw(jnp.float64(dx64), jnp.float64(dy64), jnp.float64(dz64),
                jnp.float64(qx64), jnp.float64(qy64),
                params=p, area=1.0, impl=impl, layout=layout, block_q=64, block_d=128)
    err = np.abs(np.asarray(z) - z64).max()
    assert err < 1e-9, (impl, layout, err)

# f32 vs Kahan-f32 vs f64: Kahan must not be worse than plain f32
f32 = [jnp.float32(v) for v in (dx64, dy64, dz64, qx64, qy64)]
z32, _ = aidw_interpolate(*f32, p, area=1.0, q_chunk=64, d_chunk=128)
zk, _ = aidw_interpolate_kahan(*f32, p, area=1.0, q_chunk=64, d_chunk=128)
e32 = relative_rmse(jnp.asarray(np.asarray(z32), jnp.float64), z64)
ek = relative_rmse(jnp.asarray(np.asarray(zk), jnp.float64), z64)
assert ek <= e32 * 1.05, (ek, e32)
print(f"OK f64-kernels; f32 rel-rmse={e32:.3e} kahan rel-rmse={ek:.3e}")
"""


@pytest.mark.slow
def test_f64_kernels_subprocess():
    env = dict(os.environ, JAX_ENABLE_X64="1", PYTHONPATH="src")
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."),
        timeout=600,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK f64-kernels" in r.stdout
