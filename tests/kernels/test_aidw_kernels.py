"""Per-kernel allclose sweeps against the pure-jnp oracle (interpret mode).

Sweeps shapes (aligned + ragged), block sizes, k, layouts and impls — every
Pallas kernel in repro.kernels must match ref.py within f32 tolerance.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.aidw import AIDWParams
from repro.kernels import aidw, idw
from repro.kernels.ref import aidw_ref, idw_ref
from conftest import make_points

RTOL, ATOL = 2e-4, 2e-5


def _check(impl, layout, m, n, k=10, block_q=64, block_d=128, seed=0):
    dx, dy, dz, qx, qy = make_points(m, n, seed=seed)
    p = AIDWParams(k=k, area=1.0)
    z_ref, a_ref = aidw_ref(dx, dy, dz, qx, qy, p, 1.0)
    z, a = aidw(
        dx, dy, dz, qx, qy,
        params=p, area=1.0, impl=impl, layout=layout, block_q=block_q, block_d=block_d,
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(a_ref), rtol=RTOL, atol=ATOL)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=RTOL, atol=ATOL)


ALL_VARIANTS = [
    ("naive", "soa"),
    ("naive", "aoas"),
    ("tiled", "soa"),
    ("tiled", "aoas"),
    ("fused", "soa"),
]


@pytest.mark.parametrize("impl,layout", ALL_VARIANTS)
@pytest.mark.parametrize("m,n", [(512, 256), (500, 203), (130, 77), (1024, 64)])
def test_shape_sweep(impl, layout, m, n):
    """Aligned and ragged (padding-path) shapes for every kernel variant."""
    _check(impl, layout, m, n, seed=m + n)


@pytest.mark.parametrize("impl,layout", ALL_VARIANTS)
@pytest.mark.parametrize("k", [1, 4, 10, 16])
def test_k_sweep(impl, layout, k):
    _check(impl, layout, 300, 100, k=k, seed=k)


@pytest.mark.parametrize("impl,layout", [("tiled", "soa"), ("tiled", "aoas"), ("fused", "soa")])
@pytest.mark.parametrize("block_q,block_d", [(32, 64), (64, 256), (128, 128)])
def test_block_sweep(impl, layout, block_q, block_d):
    _check(impl, layout, 700, 300, block_q=block_q, block_d=block_d, seed=block_q)


@pytest.mark.parametrize("impl,layout", ALL_VARIANTS)
def test_exact_hits(impl, layout):
    dx, dy, dz, _, _ = make_points(256, 1, seed=9)
    z, _ = aidw(
        dx, dy, dz, dx[:64], dy[:64],
        params=AIDWParams(k=8, area=1.0), area=1.0, impl=impl, layout=layout,
        block_q=32, block_d=64,
    )
    np.testing.assert_allclose(np.asarray(z), dz[:64], atol=1e-6)


@pytest.mark.parametrize("impl,layout", ALL_VARIANTS)
def test_alpha_levels_flat_reduces_to_idw(impl, layout):
    dx, dy, dz, qx, qy = make_points(300, 120, seed=21)
    p = AIDWParams(k=10, alpha_levels=(3.0,) * 5, area=1.0)
    z, a = aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl=impl, layout=layout,
                block_q=64, block_d=128)
    np.testing.assert_allclose(np.asarray(a), 3.0, atol=1e-6)
    z_idw = idw_ref(dx, dy, dz, qx, qy, 3.0)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_idw), rtol=RTOL, atol=ATOL)


@pytest.mark.parametrize("m,n", [(512, 256), (333, 130)])
@pytest.mark.parametrize("alpha", [1.0, 2.0, 3.5])
def test_idw_kernel(m, n, alpha):
    dx, dy, dz, qx, qy = make_points(m, n, seed=int(alpha * 10))
    z_ref = idw_ref(dx, dy, dz, qx, qy, alpha)
    z = idw(dx, dy, dz, qx, qy, alpha=alpha, block_q=64, block_d=128)
    np.testing.assert_allclose(np.asarray(z), np.asarray(z_ref), rtol=RTOL, atol=ATOL)


def test_layouts_agree():
    """SoA and AoaS must be bit-identical in math (only memory traffic differs)."""
    dx, dy, dz, qx, qy = make_points(512, 200, seed=30)
    p = AIDWParams(k=10, area=1.0)
    z1, a1 = aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl="tiled", layout="soa",
                  block_q=64, block_d=128)
    z2, a2 = aidw(dx, dy, dz, qx, qy, params=p, area=1.0, impl="tiled", layout="aoas",
                  block_q=64, block_d=128)
    np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-6)


def test_m_smaller_than_block():
    _check("tiled", "soa", 50, 40, k=10, block_q=64, block_d=128, seed=31)


def test_rejects_m_below_k():
    dx, dy, dz, qx, qy = make_points(8, 4, seed=32)
    with pytest.raises(ValueError):
        aidw(dx, dy, dz, qx, qy, params=AIDWParams(k=10, area=1.0), area=1.0)
