"""End-to-end behaviour tests for the paper's system: the full AIDW pipeline
(data -> kernels -> results) plus the launcher-level train/serve drivers."""

import numpy as np
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cell_is_applicable
from repro.core.aidw import AIDWParams
from repro.data.spatial import clustered_points, uniform_points
from repro.kernels import aidw, idw


def test_end_to_end_interpolation_pipeline():
    """The quickstart path: clustered field -> tiled kernel -> AIDW better
    than (or equal to) fixed-alpha IDW on held-out truth."""
    truth = lambda x, y: np.sin(4 * x) * np.cos(3 * y) + 0.5 * x
    dx, dy, _ = clustered_points(2048, seed=1, n_clusters=16, spread=0.04)
    dz = truth(dx, dy).astype(np.float32)
    qx, qy, _ = uniform_points(1024, seed=2)
    q_truth = truth(qx, qy)
    z_aidw, alpha = aidw(dx, dy, dz, qx, qy, params=AIDWParams(k=10, area=1.0), area=1.0)
    z_idw = idw(dx, dy, dz, qx, qy, alpha=2.0)
    rmse = lambda z: float(np.sqrt(np.mean((np.asarray(z) - q_truth) ** 2)))
    assert rmse(z_aidw) <= rmse(z_idw) * 1.05
    assert 0.5 <= float(np.min(alpha)) and float(np.max(alpha)) <= 4.0


def test_train_launcher_end_to_end(tmp_path):
    """launch.train: a reduced model trains, checkpoints, and resumes."""
    from repro.launch.train import main as train_main

    args = ["--arch", "mamba2-130m", "--reduced", "--steps", "6", "--batch", "2",
            "--seq", "16", "--ckpt-every", "2", "--ckpt-dir", str(tmp_path)]
    train_main(args)
    # resume from the latest checkpoint and continue
    train_main(args + ["--resume", "--steps", "8"])


def test_serve_launcher_end_to_end():
    """launch.serve: prefill + chained greedy decode produces valid tokens."""
    from repro.launch.serve import main as serve_main

    gen = serve_main(["--arch", "minitron-4b", "--reduced", "--batch", "2",
                      "--prompt-len", "8", "--gen", "4"])
    assert gen.shape == (2, 4)
    assert int(jnp.min(gen)) >= 0


def test_cell_matrix_covers_assignment():
    """10 archs x 4 shapes = 40 cells; the applicability matrix skips exactly
    the six pure-full-attention archs on long_500k."""
    cells = [(a, s) for a in ARCHS for s in SHAPES]
    assert len(cells) == 40
    skipped = [
        (a, s) for a, s in cells
        if not cell_is_applicable(ARCHS[a], SHAPES[s])[0]
    ]
    assert len(skipped) == 6
    assert all(s == "long_500k" for _, s in skipped)
    runnable = len(cells) - len(skipped)
    assert runnable == 34
