"""Integration test of the dry-run machinery itself on a REAL multi-device
mesh (8 simulated devices): build_cell -> jit(in/out shardings) -> lower ->
compile for reduced configs of a dense and a MoE arch, train + decode kinds.
This is the same code path the 512-device production dry-run exercises."""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax
import numpy as np
from repro.configs import ARCHS, ShapeConfig, smoke
from repro.launch.specs import build_cell, cost_analysis_dict
from repro.models import build_model
from repro.train.steps import make_serve_step, make_train_step
from repro.launch.dryrun import collective_census

mesh = jax.make_mesh((2, 4), ("data", "model"), devices=jax.devices())

for arch_name in ("minitron-4b", "mixtral-8x7b"):
    cfg = dataclasses.replace(smoke(ARCHS[arch_name]), d_model=64, vocab_size=256)
    model = build_model(cfg)
    # train cell
    shape = ShapeConfig("t", "train", seq_len=32, global_batch=8, accum_steps=2)
    cell = build_cell(model, cfg, shape, mesh)
    fn = make_train_step(model, cfg, shape, mesh=mesh, rules=cell["rules"])
    compiled = jax.jit(fn, in_shardings=cell["in_shardings"],
                       out_shardings=cell["out_shardings"]).lower(*cell["args"]).compile()
    hlo = compiled.as_text()
    census = collective_census(hlo)
    assert census["all-reduce"]["count"] > 0, f"{arch_name}: train must all-reduce grads"
    # decode cell
    shape = ShapeConfig("d", "decode", seq_len=64, global_batch=8)
    cell = build_cell(model, cfg, shape, mesh)
    fn = make_serve_step(model, cfg, mesh=mesh, rules=cell["rules"])
    compiled = jax.jit(fn, in_shardings=cell["in_shardings"],
                       out_shardings=cell["out_shardings"]).lower(*cell["args"]).compile()
    assert cost_analysis_dict(compiled).get("flops", 0) > 0
    print(f"OK {arch_name}")
print("OK dryrun-machinery")
"""


@pytest.mark.slow
def test_dryrun_machinery_8dev():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."), timeout=900,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK dryrun-machinery" in r.stdout
