"""Multi-device ring-AIDW correctness on 8 simulated devices (subprocess so
the forced device count never leaks into the main test process)."""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh
from repro.core.aidw import AIDWParams
from repro.core.distributed import ring_aidw, sharded_queries_aidw
from repro.kernels.ref import aidw_ref

assert len(jax.devices()) == 8
rng = np.random.default_rng(7)
m, n = 1024, 512   # divisible by 8
centers = rng.random((12, 2))
pts = np.clip(centers[rng.integers(0, 12, m)] + rng.normal(0, .02, (m, 2)), 0, 1).astype(np.float32)
dx, dy = pts[:, 0], pts[:, 1]
dz = (np.sin(6 * dx) * np.cos(6 * dy) + 2).astype(np.float32)
qx, qy = rng.random(n).astype(np.float32), rng.random(n).astype(np.float32)
p = AIDWParams(k=10, area=1.0)
z_ref, a_ref = aidw_ref(dx, dy, dz, qx, qy, p, 1.0)

# 2-D mesh: ring over the flattened (data, model) axes — the multi-pod pattern
mesh = Mesh(np.array(jax.devices()).reshape(4, 2), ("data", "model"))
z, a = ring_aidw(mesh, dx, dy, dz, qx, qy, params=p, area=1.0)
err_z = np.abs(np.asarray(z) - np.asarray(z_ref)).max()
err_a = np.abs(np.asarray(a) - np.asarray(a_ref)).max()
assert err_z < 5e-4, err_z
assert err_a < 1e-5, err_a

# ring over a single named axis, data replicated on the other
z1, a1 = ring_aidw(mesh, dx, dy, dz, qx, qy, params=p, area=1.0, axis_names=("data",))
# note: in_specs shard queries over 'data' only in this mode
err = np.abs(np.asarray(a1) - np.asarray(a_ref)).max()
assert err < 1e-5, err

# replicated-data sharded-queries mode
z2, a2 = sharded_queries_aidw(mesh, dx, dy, dz, qx, qy, params=p, area=1.0)
assert np.abs(np.asarray(z2) - np.asarray(z_ref)).max() < 5e-4

# the lowered HLO must actually contain collective-permute (ring is real)
import functools
from jax.sharding import PartitionSpec as P
lowered = jax.jit(lambda *a: ring_aidw(mesh, *a, params=p, area=1.0)).lower(dx, dy, dz, qx, qy)
txt = lowered.compile().as_text()
assert "collective-permute" in txt, "ring should lower to collective-permute"
print("OK ring-aidw 8dev")
"""


@pytest.mark.slow
def test_ring_aidw_8dev_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT],
        env=env,
        capture_output=True,
        text=True,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."),
        timeout=900,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK ring-aidw 8dev" in r.stdout
