"""Mesh-elastic checkpoint restore: a checkpoint saved under one mesh
restores onto a DIFFERENT mesh/sharding (the restart-after-resize path).
Runs on 8 simulated devices in a subprocess."""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os, tempfile
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.checkpoint import Checkpointer

tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
        "b": jnp.arange(16, dtype=jnp.float32)}

# save while sharded over an 8-way mesh
mesh8 = Mesh(np.array(jax.devices()).reshape(8), ("data",))
sh8 = {"w": NamedSharding(mesh8, P("data", None)), "b": NamedSharding(mesh8, P("data"))}
sharded = jax.tree.map(lambda a, s: jax.device_put(a, s), tree, sh8)
d = tempfile.mkdtemp()
ck = Checkpointer(d)
ck.save(7, sharded)

# restore onto a 2x2 mesh (simulated shrink from 8 to 4 chips)
mesh4 = Mesh(np.array(jax.devices()[:4]).reshape(2, 2), ("data", "model"))
sh4 = {"w": NamedSharding(mesh4, P("data", "model")), "b": NamedSharding(mesh4, P("model"))}
restored, step = ck.restore(tree, shardings=sh4)
assert step == 7
for k in tree:
    np.testing.assert_array_equal(np.asarray(restored[k]), np.asarray(tree[k]))
    assert restored[k].sharding == sh4[k], (k, restored[k].sharding)
print("OK elastic-restore")
"""


@pytest.mark.slow
def test_elastic_restore_subprocess():
    env = dict(os.environ, PYTHONPATH="src")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-c", SCRIPT], env=env, capture_output=True, text=True,
        cwd=os.path.join(os.path.dirname(__file__), "..", ".."), timeout=600,
    )
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK elastic-restore" in r.stdout
