"""Golden-oracle cross-impl drift gate.

Every EXACT implementation must reproduce the committed Kahan-reference
fixture (``tests/fixtures/aidw_golden.npz``, seeded uniform + clustered
batches) within dtype-appropriate tolerance.  Pairwise parity tests compare
impls to a freshly-computed oracle, so a change that shifts the oracle and
an impl together passes them silently; this gate pins everyone to one
absolute committed reference.  The approximating ``binned`` prefilter and
``phase2="farfield"`` are deliberately excluded — their contracts are
error-bounded, not golden-equal (see tests/engine/test_farfield.py).

Regenerate (only for an intentional semantic change, noted in the PR):
``PYTHONPATH=src python tests/fixtures/make_golden.py``.
"""

import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.aidw import AIDWParams, aidw_interpolate
from repro.engine import build_plan, execute

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "aidw_golden.npz")
# Kahan reference vs plain-f32 kernel accumulation over ~1K points: the
# committed values are ~f64-accurate, the impls accumulate in f32, so the
# gate is a few f32 ulps of headroom above the observed drift.
RTOL, ATOL = 5e-4, 5e-5
EXACT_IMPLS = ("naive", "tiled", "tiled_v2", "grid", "chunked")


@pytest.fixture(scope="module")
def golden():
    with np.load(FIXTURE) as blob:
        return {k: blob[k] for k in blob.files}


@pytest.mark.parametrize("batch", ["uniform", "clustered"])
@pytest.mark.parametrize("impl", EXACT_IMPLS)
def test_exact_impl_reproduces_golden(golden, impl, batch):
    p = AIDWParams(k=int(golden["k"]), area=float(golden["area"]))
    dx, dy, dz, qx, qy = (golden[f"{batch}_{n}"] for n in ("dx", "dy", "dz", "qx", "qy"))
    if impl == "chunked":
        z, a = aidw_interpolate(dx, dy, dz, qx, qy, p, area=float(golden["area"]),
                                q_chunk=64, d_chunk=128)
    else:
        plan = build_plan(dx, dy, dz, params=p, area=float(golden["area"]),
                          impl=impl, block_q=64, block_d=128)
        z, a = execute(plan, jnp.asarray(qx), jnp.asarray(qy))
    np.testing.assert_allclose(np.asarray(a), golden[f"{batch}_alpha"],
                               rtol=RTOL, atol=ATOL, err_msg=f"{impl} alpha drift")
    np.testing.assert_allclose(np.asarray(z), golden[f"{batch}_z"],
                               rtol=RTOL, atol=ATOL, err_msg=f"{impl} z drift")


def test_fixture_is_self_consistent(golden):
    """The committed fixture itself: sane shapes and finite values (guards
    against a truncated or mis-regenerated npz slipping into the repo)."""
    for batch in ("uniform", "clustered"):
        for name in ("dx", "dy", "dz", "qx", "qy", "z", "alpha"):
            arr = golden[f"{batch}_{name}"]
            assert arr.dtype == np.float32
            assert np.isfinite(arr).all(), f"{batch}_{name} has non-finite values"
        assert golden[f"{batch}_dx"].shape == golden[f"{batch}_dz"].shape
        assert golden[f"{batch}_z"].shape == golden[f"{batch}_qx"].shape
        a = golden[f"{batch}_alpha"]
        levels = AIDWParams().alpha_levels
        assert (a >= min(levels) - 1e-6).all() and (a <= max(levels) + 1e-6).all()
