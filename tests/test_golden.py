"""Golden-oracle cross-impl drift gate.

Every EXACT implementation must reproduce the committed Kahan-reference
fixture (``tests/fixtures/aidw_golden.npz``, seeded uniform + clustered
batches) within dtype-appropriate tolerance.  Pairwise parity tests compare
impls to a freshly-computed oracle, so a change that shifts the oracle and
an impl together passes them silently; this gate pins everyone to one
absolute committed reference.  The approximating ``binned`` prefilter is
deliberately excluded — its contract is error-bounded, not golden-equal.
The two approximating Phase-2 arms get their own pins: ``ffpin_*`` commits
the farfield plan's OUTPUT (semantic-drift gate, near-bitwise tolerance)
and ``qtree_*`` commits a Kahan reference plus the proved dipole bound the
quadtree arm must reproduce and stay within (see tests/engine/
test_farfield.py and tests/engine/test_quadtree.py for the live-oracle
versions of these contracts).

Regenerate (only for an intentional semantic change, noted in the PR):
``PYTHONPATH=src python tests/fixtures/make_golden.py``.
"""

import os

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.aidw import AIDWParams, aidw_interpolate
from repro.engine import build_plan, execute

FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures", "aidw_golden.npz")
# Kahan reference vs plain-f32 kernel accumulation over ~1K points: the
# committed values are ~f64-accurate, the impls accumulate in f32, so the
# gate is a few f32 ulps of headroom above the observed drift.
RTOL, ATOL = 5e-4, 5e-5
EXACT_IMPLS = ("naive", "tiled", "tiled_v2", "grid", "chunked")


@pytest.fixture(scope="module")
def golden():
    with np.load(FIXTURE) as blob:
        return {k: blob[k] for k in blob.files}


@pytest.mark.parametrize("batch", ["uniform", "clustered"])
@pytest.mark.parametrize("impl", EXACT_IMPLS)
def test_exact_impl_reproduces_golden(golden, impl, batch):
    p = AIDWParams(k=int(golden["k"]), area=float(golden["area"]))
    dx, dy, dz, qx, qy = (golden[f"{batch}_{n}"] for n in ("dx", "dy", "dz", "qx", "qy"))
    if impl == "chunked":
        z, a = aidw_interpolate(dx, dy, dz, qx, qy, p, area=float(golden["area"]),
                                q_chunk=64, d_chunk=128)
    else:
        plan = build_plan(dx, dy, dz, params=p, area=float(golden["area"]),
                          impl=impl, block_q=64, block_d=128)
        z, a = execute(plan, jnp.asarray(qx), jnp.asarray(qy))
    np.testing.assert_allclose(np.asarray(a), golden[f"{batch}_alpha"],
                               rtol=RTOL, atol=ATOL, err_msg=f"{impl} alpha drift")
    np.testing.assert_allclose(np.asarray(z), golden[f"{batch}_z"],
                               rtol=RTOL, atol=ATOL, err_msg=f"{impl} z drift")


def test_farfield_output_pinned(golden):
    """``phase2="farfield"`` output is pinned to the committed fixture: this
    PR family's contract is that the single-level arm is UNCHANGED while the
    quadtree arm evolves.  Tolerance covers cross-backend codegen jitter
    only — a semantic change moves values far beyond it and must come with
    a deliberate regeneration noted in the PR."""
    import warnings

    from repro.core.grid import build_grid

    p = AIDWParams(k=int(golden["k"]), area=float(golden["area"]))
    dx, dy, dz, qx, qy = (golden[f"uniform_{n}"]
                          for n in ("dx", "dy", "dz", "qx", "qy"))
    gx = int(golden["ffpin_gx"])
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                   gx=gx, gy=gx)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        plan = build_plan(dx, dy, dz, params=p, area=float(golden["area"]),
                          impl="grid", grid=g, phase2="farfield",
                          farfield_radius=int(golden["ffpin_radius"]),
                          block_q=64)
    z, a = execute(plan, jnp.asarray(qx), jnp.asarray(qy))
    np.testing.assert_allclose(np.asarray(a), golden["ffpin_alpha"],
                               rtol=0, atol=1e-6, err_msg="farfield alpha drift")
    np.testing.assert_allclose(np.asarray(z), golden["ffpin_z"],
                               rtol=2e-6, atol=2e-6, err_msg="farfield z drift")


def test_quadtree_pinned_within_proved_bound(golden):
    """``phase2="quadtree"`` against the committed Kahan reference on the
    provable tight-cluster batch: the live plan must reproduce the committed
    proved bound (<= 1e-3) and its output must stay within that bound of
    the committed reference."""
    from repro.core.accuracy import FP_SLACK_ULPS
    from repro.core.grid import build_grid

    p = AIDWParams(k=int(golden["k"]), area=float(golden["area"]))
    dx, dy, dz, qx, qy = (golden[f"qtree_{n}"]
                          for n in ("dx", "dy", "dz", "qx", "qy"))
    gx = int(golden["qtree_gx"])
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                   gx=gx, gy=gx)
    plan = build_plan(dx, dy, dz, params=p, area=float(golden["area"]),
                      impl="grid", grid=g, phase2="quadtree", block_q=64)
    bound = float(golden["qtree_bound"])
    assert bound <= 1e-3
    np.testing.assert_allclose(plan.farfield_bound, bound, rtol=1e-9,
                               err_msg="dipole bound model drift")
    z, a = execute(plan, jnp.asarray(qx), jnp.asarray(qy))
    scale = float(np.max(np.abs(golden["qtree_dz"])))
    fp_slack = (FP_SLACK_ULPS * float(np.finfo(np.float32).eps)
                * float(np.sqrt(dx.shape[0])))
    rel = float(np.max(np.abs(np.asarray(z, np.float64)
                              - golden["qtree_z"].astype(np.float64))) / scale)
    assert rel <= bound + fp_slack, (rel, bound, fp_slack)
    np.testing.assert_allclose(np.asarray(a), golden["qtree_alpha"],
                               rtol=RTOL, atol=ATOL, err_msg="quadtree alpha drift")


def test_fixture_is_self_consistent(golden):
    """The committed fixture itself: sane shapes and finite values (guards
    against a truncated or mis-regenerated npz slipping into the repo)."""
    for batch in ("uniform", "clustered", "qtree"):
        for name in ("dx", "dy", "dz", "qx", "qy", "z", "alpha"):
            arr = golden[f"{batch}_{name}"]
            assert arr.dtype == np.float32
            assert np.isfinite(arr).all(), f"{batch}_{name} has non-finite values"
        assert golden[f"{batch}_dx"].shape == golden[f"{batch}_dz"].shape
        assert golden[f"{batch}_z"].shape == golden[f"{batch}_qx"].shape
        a = golden[f"{batch}_alpha"]
        levels = AIDWParams().alpha_levels
        assert (a >= min(levels) - 1e-6).all() and (a <= max(levels) + 1e-6).all()
