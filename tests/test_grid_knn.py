"""Grid-partitioned kNN (repro.core.grid): layout invariants, exact parity
with the brute-force oracle, boundary/empty-cell cases, and the ring-search
never-misses-a-neighbour property (hypothesis)."""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.grid import (
    block_count,
    build_grid,
    cell_of,
    cover_radius,
    grid_knn,
    grid_r_obs,
    morton_ids,
    required_radius,
    safe_radius,
)
from conftest import make_points, require_hypothesis


def _brute_knn(px, py, qx, qy, k):
    d2 = (np.asarray(qx)[:, None] - np.asarray(px)[None, :]) ** 2 + (
        np.asarray(qy)[:, None] - np.asarray(py)[None, :]
    ) ** 2
    return np.sort(d2, axis=1)[:, :k]


# ------------------------------------------------------------ build invariants
def test_build_grid_layout_roundtrip():
    dx, dy, dz, _, _ = make_points(700, 1, seed=1)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz))
    counts = np.asarray(g.counts)
    assert counts.sum() == 700
    assert g.cap == counts.max()
    # every point appears exactly once in the padded layout
    cell_x = np.asarray(g.cell_x)
    real = cell_x[cell_x < 1e30]
    assert real.shape[0] == 700
    np.testing.assert_array_equal(np.sort(real), np.sort(dx))
    # the sentinel row is entirely padding
    assert (cell_x[-1] >= 1e30).all()


def test_integral_image_matches_counts():
    dx, dy, dz, _, _ = make_points(400, 1, seed=2, clustered=True)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy))
    counts = np.asarray(g.counts)
    rng = np.random.default_rng(0)
    for _ in range(20):
        cx, cy, r = rng.integers(0, g.gx), rng.integers(0, g.gy), rng.integers(0, 5)
        got = int(block_count(g, jnp.int32(cx), jnp.int32(cy), jnp.int32(r)))
        xlo, xhi = max(cx - r, 0), min(cx + r + 1, g.gx)
        ylo, yhi = max(cy - r, 0), min(cy + r + 1, g.gy)
        assert got == counts[ylo:yhi, xlo:xhi].sum()


# ------------------------------------------------------------------ knn parity
@pytest.mark.parametrize("clustered", [False, True])
@pytest.mark.parametrize("k", [1, 4, 10, 16])
def test_grid_knn_matches_brute(clustered, k):
    dx, dy, dz, qx, qy = make_points(800, 300, seed=k, clustered=clustered)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy))
    best = np.asarray(grid_knn(g, jnp.asarray(qx), jnp.asarray(qy), k))
    np.testing.assert_allclose(best, _brute_knn(dx, dy, qx, qy, k), rtol=1e-6, atol=1e-12)


def test_grid_knn_queries_outside_bounds():
    """Clamped home cells keep the ring bound valid for out-of-grid queries."""
    dx, dy, _, _, _ = make_points(500, 1, seed=5)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy))
    qx = np.asarray([-0.7, 1.9, 0.5, -0.1, 1.05], np.float32)
    qy = np.asarray([1.6, -0.3, 2.5, -0.9, 0.5], np.float32)
    best = np.asarray(grid_knn(g, jnp.asarray(qx), jnp.asarray(qy), 8))
    np.testing.assert_allclose(best, _brute_knn(dx, dy, qx, qy, 8), rtol=1e-6)


def test_grid_knn_queries_on_cell_boundaries():
    """Queries exactly on grid lines (ties between neighbouring cells)."""
    dx, dy, _, _, _ = make_points(600, 1, seed=6)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), gx=8, gy=8)
    edges_x = np.asarray(g.origin[0] + np.arange(9) * g.cell_size[0], np.float32)
    edges_y = np.asarray(g.origin[1] + np.arange(9) * g.cell_size[1], np.float32)
    qx, qy = map(np.ravel, np.meshgrid(edges_x, edges_y))
    best = np.asarray(grid_knn(g, jnp.asarray(qx), jnp.asarray(qy), 10))
    np.testing.assert_allclose(best, _brute_knn(dx, dy, qx, qy, 10), rtol=1e-6)


def test_grid_knn_with_empty_cells():
    """Two tight far-apart clusters on a fine grid: most cells empty, and
    queries in the void must ring-expand across them without missing."""
    rng = np.random.default_rng(7)
    a = 0.02 * rng.random((60, 2)).astype(np.float32)
    b = 0.98 + 0.02 * rng.random((60, 2)).astype(np.float32)
    pts = np.concatenate([a, b])
    g = build_grid(jnp.asarray(pts[:, 0]), jnp.asarray(pts[:, 1]), gx=32, gy=32)
    assert (np.asarray(g.counts) == 0).mean() > 0.9
    qx = rng.random(50).astype(np.float32)
    qy = rng.random(50).astype(np.float32)
    best = np.asarray(grid_knn(g, jnp.asarray(qx), jnp.asarray(qy), 10))
    np.testing.assert_allclose(best, _brute_knn(pts[:, 0], pts[:, 1], qx, qy, 10), rtol=1e-6)


def test_grid_knn_identical_points():
    """Duplicate coordinates (all-equal distances) must fill k slots."""
    px = np.full(30, 0.5, np.float32)
    py = np.full(30, 0.5, np.float32)
    g = build_grid(jnp.asarray(px), jnp.asarray(py))
    best = np.asarray(grid_knn(g, jnp.asarray([0.5, 0.1]).astype(np.float32),
                               jnp.asarray([0.5, 0.9]).astype(np.float32), 5))
    np.testing.assert_allclose(best, _brute_knn(px, py, [0.5, 0.1], [0.5, 0.9], 5), rtol=1e-6)


def test_grid_r_obs_matches_reference():
    dx, dy, dz, qx, qy = make_points(512, 200, seed=8, clustered=True)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy))
    r_obs = np.asarray(grid_r_obs(g, jnp.asarray(qx), jnp.asarray(qy), 10))
    ref = np.sqrt(_brute_knn(dx, dy, qx, qy, 10)).mean(axis=1)
    np.testing.assert_allclose(r_obs, ref, rtol=1e-5)


# --------------------------------------------------------------- radius bounds
@pytest.mark.parametrize("clustered", [False, True])
@pytest.mark.parametrize("far_queries", [False, True])
def test_safe_radius_contains_true_neighbours(clustered, far_queries):
    """The occupancy-only bound used by the Pallas grid kernel: all true k
    nearest neighbours lie within Chebyshev ``safe_radius`` of the home cell.
    ``far_queries`` stretches queries to [-3, 3]^2 — the overhang-corrected
    bound must stay sound well outside the grid bbox."""
    k = 10
    dx, dy, _, qx, qy = make_points(600, 250, seed=11, clustered=clustered)
    if far_queries:
        qx = (qx * 6.0 - 3.0).astype(np.float32)
        qy = (qy * 6.0 - 3.0).astype(np.float32)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy))
    cx, cy, r_safe_j = safe_radius(g, jnp.asarray(qx), jnp.asarray(qy), k)
    r_need = np.asarray(required_radius(g, cx, cy, k))
    r_safe = np.asarray(r_safe_j)
    assert (r_safe >= r_need).all()
    assert (r_safe <= np.asarray(cover_radius(g, cx, cy))).all()
    d2 = (qx[:, None] - dx[None, :]) ** 2 + (qy[:, None] - dy[None, :]) ** 2
    idx = np.argsort(d2, axis=1)[:, :k]
    pcx, pcy = map(np.asarray, cell_of(g, jnp.asarray(dx), jnp.asarray(dy)))
    cheb = np.maximum(
        np.abs(pcx[idx] - np.asarray(cx)[:, None]),
        np.abs(pcy[idx] - np.asarray(cy)[:, None]),
    ).max(axis=1)
    assert (cheb <= r_safe).all()


def test_morton_ids_locality():
    """Morton order sorts the 4 quadrant cells of any 2x2 block contiguously."""
    cx, cy = jnp.asarray([0, 1, 0, 1]), jnp.asarray([0, 0, 1, 1])
    ids = np.asarray(morton_ids(cx, cy))
    np.testing.assert_array_equal(np.sort(ids), [0, 1, 2, 3])


# ------------------------------------------------------- hypothesis properties
def test_ring_expansion_never_misses_property():
    """Property: ring expansion NEVER misses a true neighbour — for arbitrary
    point sets, query positions (inside or outside the grid), k, and grid
    resolutions, grid_knn equals the brute-force k smallest distances."""
    require_hypothesis()
    from hypothesis import given, settings, strategies as st

    finite = st.floats(-2.0, 3.0, allow_nan=False, width=32)
    # grid resolution is drawn from a small set so the jitted ring search is
    # compiled a handful of times, not once per example
    resolutions = st.sampled_from([1, 2, 5, 16])

    @settings(deadline=None, max_examples=30)
    @given(
        pts=st.lists(st.tuples(finite, finite), min_size=12, max_size=120),
        qs=st.lists(st.tuples(finite, finite), min_size=1, max_size=25),
        k=st.integers(1, 10),
        g=resolutions,
    )
    def run(pts, qs, k, g):
        pts = np.asarray(pts, np.float32)
        qs = np.asarray(qs, np.float32)
        k = min(k, pts.shape[0])
        grid = build_grid(jnp.asarray(pts[:, 0]), jnp.asarray(pts[:, 1]), gx=g, gy=g)
        best = np.asarray(
            grid_knn(grid, jnp.asarray(qs[:, 0]), jnp.asarray(qs[:, 1]), k)
        )
        ref = _brute_knn(pts[:, 0], pts[:, 1], qs[:, 0], qs[:, 1], k)
        np.testing.assert_allclose(best, ref, rtol=1e-5, atol=1e-10)

    run()
