"""Regenerate the committed golden-oracle fixture ``aidw_golden.npz``.

Two seeded batches (uniform + clustered data, uniform queries) with
Kahan-compensated reference interpolants and alphas
(``core.accuracy.aidw_interpolate_kahan`` — ~f64-quality accumulation at
f32 cost).  ``tests/test_golden.py`` asserts every EXACT impl reproduces
these values within dtype-appropriate tolerance, pinning the whole impl
family to one absolute reference across PRs (pairwise parity tests cannot
see a drift that moves two impls together).

Beyond the exact-impl batches, the fixture pins the two approximating
Phase-2 arms:

* ``ffpin_*`` — the ``phase2="farfield"`` plan's committed OUTPUT on the
  uniform batch (gx=12, radius=2): a semantic-regression gate that the
  single-level arm is unchanged across PRs;
* ``qtree_*`` — a tight-cluster batch where the quadtree dipole bound
  PROVES rtol=1e-3, with its Kahan reference and the proved bound recorded
  at generation time; ``test_golden.py`` asserts the live plan reproduces
  the bound and stays within it against the committed reference.

Run from the repo root (only when the reference semantics intentionally
change — note it in the PR):

    PYTHONPATH=src python tests/fixtures/make_golden.py
"""

import os
import sys
import warnings

import numpy as np
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # conftest
from conftest import make_points  # noqa: E402

from repro.core.accuracy import aidw_interpolate_kahan  # noqa: E402
from repro.core.aidw import AIDWParams  # noqa: E402
from repro.core.grid import build_grid  # noqa: E402
from repro.engine import build_plan, execute  # noqa: E402

M, N, K = 900, 320, 10
QT_GX, QT_M = 12, 4000
OUT = os.path.join(os.path.dirname(__file__), "aidw_golden.npz")


def _quadtree_batch(seed=303):
    """Per-cell clusters far below the cell scale (sub-cell dispersion) with
    z noise INSIDE each cluster — the configuration where the quadtree
    dipole bound proves rtol=1e-3 and the single-level model cannot."""
    rng = np.random.default_rng(seed)
    centers = (np.stack(np.meshgrid(np.arange(QT_GX), np.arange(QT_GX)), -1)
               .reshape(-1, 2) + 0.5) / QT_GX
    pts = (centers[rng.integers(0, QT_GX * QT_GX, QT_M)]
           + rng.normal(0, 1e-4, (QT_M, 2)))
    pts = np.clip(pts, 0.0, 1.0).astype(np.float32)
    dx, dy = pts[:, 0], pts[:, 1]
    dz = (np.sin(6 * dx) * np.cos(6 * dy) + 2.0
          + 0.3 * rng.standard_normal(QT_M)).astype(np.float32)
    q = rng.random((N, 2)).astype(np.float32)
    return dx, dy, dz, q[:, 0], q[:, 1]


def main():
    params = AIDWParams(k=K, area=1.0)
    blobs = {"k": np.int32(K), "area": np.float32(1.0)}
    for name, clustered, seed in (("uniform", False, 101), ("clustered", True, 202)):
        dx, dy, dz, qx, qy = make_points(M, N, seed=seed, clustered=clustered)
        z_ref, a_ref = aidw_interpolate_kahan(
            dx, dy, dz, qx, qy, params, area=1.0, q_chunk=64, d_chunk=128
        )
        blobs.update({
            f"{name}_dx": dx, f"{name}_dy": dy, f"{name}_dz": dz,
            f"{name}_qx": qx, f"{name}_qy": qy,
            f"{name}_z": np.asarray(z_ref), f"{name}_alpha": np.asarray(a_ref),
        })

    # farfield pin: committed output of the single-level arm on the uniform
    # batch — any semantic drift across PRs trips the golden gate.
    dx, dy, dz, qx, qy = (blobs[f"uniform_{n}"]
                          for n in ("dx", "dy", "dz", "qx", "qy"))
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                   gx=12, gy=12)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")  # honest-bound warning at this radius
        plan = build_plan(dx, dy, dz, params=params, area=1.0, impl="grid",
                          grid=g, phase2="farfield", farfield_radius=2,
                          block_q=64)
    z, a = execute(plan, jnp.asarray(qx), jnp.asarray(qy))
    blobs.update({"ffpin_z": np.asarray(z), "ffpin_alpha": np.asarray(a),
                  "ffpin_radius": np.int32(2), "ffpin_gx": np.int32(12)})

    # quadtree pin: Kahan reference + the proved dipole bound on the
    # provable batch.
    dx, dy, dz, qx, qy = _quadtree_batch()
    z_ref, a_ref = aidw_interpolate_kahan(dx, dy, dz, qx, qy, params,
                                          area=1.0, q_chunk=64, d_chunk=128)
    g = build_grid(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz),
                   gx=QT_GX, gy=QT_GX)
    plan = build_plan(dx, dy, dz, params=params, area=1.0, impl="grid",
                      grid=g, phase2="quadtree", block_q=64)
    assert plan.farfield_bound <= 1e-3, "qtree batch must be provable"
    blobs.update({
        "qtree_dx": dx, "qtree_dy": dy, "qtree_dz": dz,
        "qtree_qx": qx, "qtree_qy": qy,
        "qtree_z": np.asarray(z_ref), "qtree_alpha": np.asarray(a_ref),
        "qtree_bound": np.float64(plan.farfield_bound),
        "qtree_gx": np.int32(QT_GX),
    })
    np.savez_compressed(OUT, **blobs)
    print(f"wrote {OUT} ({os.path.getsize(OUT)} bytes)")


if __name__ == "__main__":
    main()
