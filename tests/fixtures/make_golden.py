"""Regenerate the committed golden-oracle fixture ``aidw_golden.npz``.

Two seeded batches (uniform + clustered data, uniform queries) with
Kahan-compensated reference interpolants and alphas
(``core.accuracy.aidw_interpolate_kahan`` — ~f64-quality accumulation at
f32 cost).  ``tests/test_golden.py`` asserts every EXACT impl reproduces
these values within dtype-appropriate tolerance, pinning the whole impl
family to one absolute reference across PRs (pairwise parity tests cannot
see a drift that moves two impls together).

Run from the repo root (only when the reference semantics intentionally
change — note it in the PR):

    PYTHONPATH=src python tests/fixtures/make_golden.py
"""

import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # conftest
from conftest import make_points  # noqa: E402

from repro.core.accuracy import aidw_interpolate_kahan  # noqa: E402
from repro.core.aidw import AIDWParams  # noqa: E402

M, N, K = 900, 320, 10
OUT = os.path.join(os.path.dirname(__file__), "aidw_golden.npz")


def main():
    params = AIDWParams(k=K, area=1.0)
    blobs = {"k": np.int32(K), "area": np.float32(1.0)}
    for name, clustered, seed in (("uniform", False, 101), ("clustered", True, 202)):
        dx, dy, dz, qx, qy = make_points(M, N, seed=seed, clustered=clustered)
        z_ref, a_ref = aidw_interpolate_kahan(
            dx, dy, dz, qx, qy, params, area=1.0, q_chunk=64, d_chunk=128
        )
        blobs.update({
            f"{name}_dx": dx, f"{name}_dy": dy, f"{name}_dz": dz,
            f"{name}_qx": qx, f"{name}_qy": qy,
            f"{name}_z": np.asarray(z_ref), f"{name}_alpha": np.asarray(a_ref),
        })
    np.savez_compressed(OUT, **blobs)
    print(f"wrote {OUT} ({os.path.getsize(OUT)} bytes)")


if __name__ == "__main__":
    main()
