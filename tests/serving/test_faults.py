"""Fault-injection harness semantics (serving/faults.py): arming, firing
order, times-bounded firings, error/delay/value/transform application, and
guaranteed disarm on context exit — the deterministic substrate every
recover/degrade test in test_reestimator.py stands on."""

import time

import pytest

from repro.serving import faults


def test_fire_is_noop_when_nothing_armed():
    assert faults.fire("reestimator.build") is None
    sentinel = {"overflow_queries": 3}
    assert faults.fire("reestimator.stats", sentinel) is sentinel
    assert faults.active_points() == ()


def test_unknown_point_rejected_in_inject_and_fire():
    with pytest.raises(ValueError, match="unknown injection point"):
        with faults.inject("reestimator.typo"):
            pass
    with pytest.raises(ValueError, match="unknown injection point"):
        faults.fire("registry.typo")


def test_error_injection_counts_and_disarms_on_exit():
    class Boom(RuntimeError):
        pass

    with faults.inject("reestimator.build", error=Boom) as fault:
        with pytest.raises(Boom):
            faults.fire("reestimator.build")
        assert fault.fired == 1
    # context exited: the fault is gone
    assert faults.fire("reestimator.build") is None
    assert faults.active_points() == ()


def test_error_instance_carries_its_message():
    err = ValueError("specific message")
    with faults.inject("reestimator.build", error=err):
        with pytest.raises(ValueError, match="specific message"):
            faults.fire("reestimator.build")


def test_times_bounds_firings_then_passes_through():
    class Boom(RuntimeError):
        pass

    with faults.inject("reestimator.build", error=Boom, times=2) as fault:
        for _ in range(2):
            with pytest.raises(Boom):
                faults.fire("reestimator.build")
        # third firing: exhausted, passes through
        assert faults.fire("reestimator.build") is None
        assert fault.fired == 2


def test_value_and_transform_override():
    with faults.inject("reestimator.capacity", value=7):
        assert faults.fire("reestimator.capacity", 4096) == 7
    with faults.inject("reestimator.stats",
                       transform=lambda s: dict(s, overflow_queries=99)):
        out = faults.fire("reestimator.stats", {"overflow_queries": 0})
        assert out["overflow_queries"] == 99
    with pytest.raises(ValueError, match="not both"):
        with faults.inject("reestimator.capacity", value=1, transform=int):
            pass


def test_delay_sleeps_before_passthrough():
    t0 = time.monotonic()
    with faults.inject("registry.swap", delay=0.05):
        assert faults.fire("registry.swap", "key") == "key"
    assert time.monotonic() - t0 >= 0.05


def test_nested_faults_fire_in_arming_order():
    with faults.inject("reestimator.capacity", transform=lambda v: v + 1):
        with faults.inject("reestimator.capacity", transform=lambda v: v * 10):
            # outer armed first: (1 + 1) * 10
            assert faults.fire("reestimator.capacity", 1) == 20
        assert faults.fire("reestimator.capacity", 1) == 2


def test_crashing_with_block_still_disarms():
    with pytest.raises(KeyError):
        with faults.inject("reestimator.build", error=RuntimeError):
            raise KeyError("test crash inside the block")
    assert faults.active_points() == ()


def test_times_validation():
    with pytest.raises(ValueError, match="times"):
        with faults.inject("reestimator.build", error=RuntimeError, times=0):
            pass
