"""PlanRegistry contract (serving/registry.py): bounded LRU + eviction,
hit/miss/eviction/swap counters, identity-guard lifetime (GC eviction, id
mismatch = miss), warmup-before-publish, and the atomic hot-swap — readers
concurrent with a (fault-widened) swap only ever see a complete plan."""

import gc
import threading

import numpy as np
import pytest

from repro.core.aidw import AIDWParams
from repro.engine import build_plan
from repro.serving import PlanRegistry, default_registry, faults, plan_key

P = AIDWParams(k=5, area=1.0)


def _data(seed, m=64):
    rng = np.random.default_rng(seed)
    dx = rng.random(m).astype(np.float32)
    dy = rng.random(m).astype(np.float32)
    dz = (dx + dy).astype(np.float32)
    return dx, dy, dz


def _plan(seed=0):
    # chunked: the cheapest real plan (no kernels, no grid snapshot)
    return build_plan(*_data(seed), params=P, area=1.0, impl="chunked")


def test_register_get_hit_miss_counters():
    reg = PlanRegistry(max_plans=4)
    assert reg.get("absent") is None
    plan = _plan(0)
    assert reg.register("a", plan) is plan
    assert reg.get("a") is plan
    assert "a" in reg and "b" not in reg
    s = reg.stats()
    assert (s["hits"], s["misses"], s["size"]) == (1, 1, 1)


def test_lru_bound_evicts_oldest_and_get_refreshes_recency():
    reg = PlanRegistry(max_plans=2)
    plans = {k: _plan(i) for i, k in enumerate("abc")}
    reg.register("a", plans["a"])
    reg.register("b", plans["b"])
    assert reg.get("a") is plans["a"]  # refresh: "b" is now the LRU entry
    reg.register("c", plans["c"])
    assert len(reg) == 2
    assert reg.get("b") is None
    assert reg.get("a") is plans["a"] and reg.get("c") is plans["c"]
    assert reg.stats()["evictions"] == 1


def test_guards_gc_evicts_entry():
    reg = PlanRegistry()
    dx, dy, dz = _data(1)
    plan = build_plan(dx, dy, dz, params=P, area=1.0, impl="chunked")
    reg.register("g", plan, guards=(dx, dy, dz))
    assert reg.get("g", live=(dx, dy, dz)) is plan
    del dx, dy, dz
    gc.collect()
    assert len(reg) == 0
    assert reg.stats()["evictions"] >= 1


def test_guard_identity_mismatch_is_miss():
    reg = PlanRegistry()
    dx, dy, dz = _data(2)
    plan = build_plan(dx, dy, dz, params=P, area=1.0, impl="chunked")
    reg.register("g", plan, guards=(dx, dy, dz))
    other = dx.copy()
    assert reg.get("g", live=(other, dy, dz)) is None
    assert len(reg) == 0  # the stale entry was dropped, not served


def test_get_or_build_builds_once():
    reg = PlanRegistry()
    calls = []

    def build():
        calls.append(1)
        return _plan(3)

    p1 = reg.get_or_build("k", build)
    p2 = reg.get_or_build("k", build)
    assert p1 is p2 and len(calls) == 1


def test_swap_replaces_atomically_and_counts():
    reg = PlanRegistry()
    old, new = _plan(4), _plan(5)
    reg.register("k", old)
    assert reg.swap("k", new) is old
    assert reg.get("k") is new
    assert reg.stats()["swaps"] == 1
    with pytest.raises(KeyError):
        reg.swap("absent", new)


def test_swap_with_failing_warmup_keeps_old_plan():
    reg = PlanRegistry()
    old, new = _plan(6), _plan(7)
    reg.register("k", old)
    with pytest.raises(Exception):
        # a warmup batch execute() cannot consume fails BEFORE publication
        reg.swap("k", new, warmup=("not-an-array", None))
    assert reg.get("k") is old
    assert reg.stats()["swaps"] == 0


def test_warmup_runs_execute_before_publish():
    reg = PlanRegistry()
    plan = _plan(8)
    qx = np.linspace(0.1, 0.9, 16).astype(np.float32)
    reg.register("k", plan, warmup=(qx, qx))
    assert reg.get("k") is plan


def test_concurrent_readers_never_see_torn_state_during_swap():
    reg = PlanRegistry()
    old, new = _plan(9), _plan(10)
    reg.register("k", old)
    seen, stop = [], threading.Event()

    def reader():
        while not stop.is_set():
            seen.append(reg.get("k"))

    threads = [threading.Thread(target=reader) for _ in range(4)]
    for t in threads:
        t.start()
    # widen the swap critical section so readers overlap it
    with faults.inject("registry.swap", delay=0.05):
        reg.swap("k", new)
    stop.set()
    for t in threads:
        t.join()
    assert seen and all(p is old or p is new for p in seen)
    assert reg.get("k") is new


def test_clear_resets_entries_and_counters():
    reg = PlanRegistry()
    reg.register("a", _plan(11))
    reg.get("a")
    reg.clear()
    s = reg.stats()
    assert (len(reg), s["hits"], s["misses"], s["evictions"], s["swaps"]) \
        == (0, 0, 0, 0, 0)


def test_max_plans_validation():
    with pytest.raises(ValueError, match="max_plans"):
        PlanRegistry(max_plans=0)


def test_plan_key_hashable_and_unhashable_config():
    dx, dy, dz = _data(12)
    k1 = plan_key(dx, dy, dz, {"impl": "grid", "block_q": 64})
    k2 = plan_key(dx, dy, dz, {"impl": "grid", "block_q": 64})
    assert k1 == k2 and hash(k1) == hash(k2)
    assert plan_key(dx, dy, dz, {"grid": [1, 2]}) is None  # unhashable value


def test_default_registry_is_a_singleton_plan_registry():
    reg = default_registry()
    assert reg is default_registry()
    assert isinstance(reg, PlanRegistry)
