"""Capacity re-estimator state machine (serving/reestimator.py) — the PR-9
acceptance criteria, driven deterministically through the fault harness:

* recovery proof: a persistent-overflow workload triggers a background
  re-plan + atomic swap, ``overflow_queries`` drops to 0 within
  ``<= 2 * PERSISTENT_OVERFLOW_BATCHES`` batches of the streak trigger, and
  EVERY batch served before / during / after the swap is bitwise equal to a
  fresh-plan reference (old plan before the swap, bumped plan after);
* injected build failures retry with bounded backoff and then either
  succeed (``times``-bounded fault) or degrade with ONE typed
  :class:`PlanDegradedWarning` — results staying exact via the blend arms;
* capacity-cap exhaustion degrades without a build attempt;
* synthetic overflow streaks injected at ``reestimator.stats`` flow through
  the REAL streak machinery.
"""

import warnings

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core.aidw import AIDWParams
from repro.engine import build_plan, execute, replan_with_capacity
from repro.engine.execute import PERSISTENT_OVERFLOW_BATCHES
from repro.errors import CapacityOverflowWarning, PlanBuildError, PlanDegradedWarning
from repro.serving import CapacityReestimator, PlanRegistry, faults
from repro.serving.reestimator import DEGRADED, HEALTHY, REPLANNING

P = AIDWParams(k=10, area=1.0, r_max=64.0)
M = 4096
GROWTH = 2.0


def _dataset():
    rng = np.random.default_rng(19)
    dx = rng.random(M).astype(np.float32)
    dy = rng.random(M).astype(np.float32)
    dz = (np.sin(3 * dx) * np.cos(2 * dy)).astype(np.float32)
    return dx, dy, dz


def _base_plan(data):
    # query_occupancy far denser than the serving batches: the capacity
    # model undersizes on purpose, so out-of-bbox batches overflow every
    # time (the deterministic "overflow storm" of tests/engine/test_blend)
    return build_plan(*data, params=P, area=1.0, impl="grid",
                      query_occupancy=64.0)


def _storm_batch(seed=20, n=64):
    rng = np.random.default_rng(seed)
    qx = (rng.random(n) * 6 - 3).astype(np.float32)
    qy = (rng.random(n) * 6 - 3).astype(np.float32)
    return jnp.asarray(qx), jnp.asarray(qy)


def _clean_batch(seed=21, n=64):
    rng = np.random.default_rng(seed)
    qx = (0.4 + 0.05 * rng.random(n)).astype(np.float32)
    qy = (0.4 + 0.05 * rng.random(n)).astype(np.float32)
    return jnp.asarray(qx), jnp.asarray(qy)


def _reestimator(data, **kw):
    plan = _base_plan(data)
    reg = PlanRegistry()
    kw.setdefault("backoff", 0.0)
    return reg, plan, CapacityReestimator(reg, "serve", plan, **kw)


def test_recovery_proof_overflow_drops_to_zero_bitwise():
    """The headline acceptance criterion."""
    data = _dataset()
    reg, plan, re_ = _reestimator(data)
    ref_old = _base_plan(data)  # fresh, never-swapped reference build
    assert plan.cand_capacity == ref_old.cand_capacity
    qx, qy = _storm_batch()

    # drive the streak to the trigger; every pre-swap batch must be bitwise
    # equal to the fresh old-plan reference (serving is never disturbed)
    z_ref, a_ref = execute(ref_old, qx, qy)
    need_max = 0
    with pytest.warns(CapacityOverflowWarning):
        for batch in range(1, PERSISTENT_OVERFLOW_BATCHES + 1):
            z, a, st = re_.execute(qx, qy)
            assert int(st["overflow_queries"]) > 0
            need_max = max(need_max, int(st["cand_need_max"]))
            np.testing.assert_array_equal(np.asarray(z), np.asarray(z_ref))
            np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref))
    trigger_batch = PERSISTENT_OVERFLOW_BATCHES
    assert st["persistent_overflow"] is True

    assert re_.join() == HEALTHY
    # the swapped plan equals a fresh build at the re-estimator's target
    target = min(max(int(ref_old.cand_capacity * GROWTH), need_max), M)
    ref_new = replan_with_capacity(ref_old, min_cand_capacity=target,
                                   min_p2_capacity=target)
    assert re_.plan.cand_capacity == ref_new.cand_capacity > plan.cand_capacity

    # post-swap: the SAME storm no longer overflows, bitwise vs fresh plan
    z_new_ref, a_new_ref = execute(ref_new, qx, qy)
    z2, a2, st2 = re_.execute(qx, qy)
    recovered_batch = trigger_batch + 1
    assert int(st2["overflow_queries"]) == 0
    assert recovered_batch - trigger_batch <= 2 * PERSISTENT_OVERFLOW_BATCHES
    np.testing.assert_array_equal(np.asarray(z2), np.asarray(z_new_ref))
    np.testing.assert_array_equal(np.asarray(a2), np.asarray(a_new_ref))
    s = re_.stats()
    assert (s["triggers"], s["swaps"], s["degraded"]) == (1, 1, 0)
    assert reg.stats()["swaps"] == 1


def test_serving_continues_on_old_plan_during_slow_replan():
    data = _dataset()
    _, plan, re_ = _reestimator(data)
    ref_old = _base_plan(data)
    qx, qy = _storm_batch()
    z_ref, a_ref = execute(ref_old, qx, qy)
    # a slow background build: the swap cannot have happened yet when the
    # next batch is served
    with faults.inject("reestimator.build", delay=1.0):
        with pytest.warns(CapacityOverflowWarning):
            for _ in range(PERSISTENT_OVERFLOW_BATCHES):
                re_.execute(qx, qy)
        assert re_.state == REPLANNING
        z, a, st = re_.execute(qx, qy)  # served DURING the re-plan
        assert re_.state == REPLANNING
        assert int(st["overflow_queries"]) > 0  # still the old plan...
        np.testing.assert_array_equal(np.asarray(z), np.asarray(z_ref))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref))
    assert re_.join(timeout=30.0) == HEALTHY  # ...and the swap still lands
    _, _, st2 = re_.execute(qx, qy)
    assert int(st2["overflow_queries"]) == 0


def test_build_failures_retry_then_succeed():
    data = _dataset()
    _, _, re_ = _reestimator(data, max_retries=3)
    qx, qy = _storm_batch()
    with faults.inject("reestimator.build", error=RuntimeError("flaky build"),
                       times=2) as fault:
        with pytest.warns(CapacityOverflowWarning):
            for _ in range(PERSISTENT_OVERFLOW_BATCHES):
                re_.execute(qx, qy)
        assert re_.join() == HEALTHY
    assert fault.fired == 2
    s = re_.stats()
    assert s["build_failures"] == 2 and s["swaps"] == 1 and s["degraded"] == 0
    _, _, st = re_.execute(qx, qy)
    assert int(st["overflow_queries"]) == 0


def test_build_failure_exhausts_retries_and_degrades_with_typed_warning():
    data = _dataset()
    _, plan, re_ = _reestimator(data, max_retries=2)
    ref_old = _base_plan(data)
    qx, qy = _storm_batch()
    z_ref, a_ref = execute(ref_old, qx, qy)
    # record everything: with backoff=0 the degrade can land DURING the
    # trigger batch, so the typed warning may surface on that execute or
    # the next one — either way it must appear exactly once, on the
    # serving thread
    with faults.inject("reestimator.build",
                       error=RuntimeError("broken build")) as fault, \
            warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for _ in range(PERSISTENT_OVERFLOW_BATCHES):
            re_.execute(qx, qy)
        assert re_.join() == DEGRADED
        z, a, st = re_.execute(qx, qy)
    assert fault.fired == 2  # bounded: exactly max_retries attempts
    assert isinstance(re_.last_error, PlanBuildError)
    assert any(issubclass(w.category, CapacityOverflowWarning) for w in rec)
    degr = [w for w in rec if issubclass(w.category, PlanDegradedWarning)]
    assert len(degr) == 1 and "degraded" in str(degr[0].message)
    # the batch is still served exactly through the blend arm of the OLD plan
    assert int(st["overflow_queries"]) > 0
    np.testing.assert_array_equal(np.asarray(z), np.asarray(z_ref))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(a_ref))
    # no re-warn, no re-trigger on further batches
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        _, _, st = re_.execute(*_clean_batch())
    assert re_.state == DEGRADED
    assert re_.stats()["triggers"] == 1
    # reset re-arms the machine
    re_.reset()
    assert re_.state == HEALTHY and re_.last_error is None


def test_capacity_cap_exhaustion_degrades_without_build():
    data = _dataset()
    plan = _base_plan(data)
    reg = PlanRegistry()
    re_ = CapacityReestimator(reg, "serve", plan, backoff=0.0,
                              capacity_cap=plan.cand_capacity)
    qx, qy = _storm_batch()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        for _ in range(PERSISTENT_OVERFLOW_BATCHES):
            re_.execute(qx, qy)
        assert re_.join() == DEGRADED
        re_.execute(qx, qy)
    s = re_.stats()
    assert s["replans"] == 0 and s["build_failures"] == 0  # never attempted
    degr = [w for w in rec if issubclass(w.category, PlanDegradedWarning)]
    assert len(degr) == 1 and "capacity cap" in str(degr[0].message)
    assert re_.plan is plan  # nothing was swapped


def test_injected_capacity_override_forces_degrade():
    data = _dataset()
    _, plan, re_ = _reestimator(data)
    qx, qy = _storm_batch()
    with faults.inject("reestimator.capacity", value=plan.cand_capacity):
        with pytest.warns(CapacityOverflowWarning):
            for _ in range(PERSISTENT_OVERFLOW_BATCHES):
                re_.execute(qx, qy)
        assert re_.join() == DEGRADED


def test_synthetic_streak_via_stats_injection_drives_real_machinery():
    """A CLEAN workload + a stats transform fabricating overflow: the real
    streak counter, trigger, re-plan and swap all run."""
    data = _dataset()
    _, plan, re_ = _reestimator(data)
    qx, qy = _clean_batch()
    fake = dict(overflow_queries=7, cand_need_max=M)
    with faults.inject("reestimator.stats",
                       transform=lambda s: dict(s, **fake),
                       times=PERSISTENT_OVERFLOW_BATCHES):
        with pytest.warns(CapacityOverflowWarning):
            for _ in range(PERSISTENT_OVERFLOW_BATCHES):
                _, _, st = re_.execute(qx, qy)
                assert int(st["overflow_queries"]) == 7
    assert re_.join() == HEALTHY
    assert re_.plan.cand_capacity == M  # bumped to the injected need
    assert re_.stats()["swaps"] == 1
    # injection exhausted: the next batch reports the true (clean) stats
    _, _, st = re_.execute(qx, qy)
    assert int(st["overflow_queries"]) == 0


def test_stale_plan_evidence_does_not_retrigger_after_swap():
    """A batch in flight while the swap lands carries the OLD plan's streak;
    its persistent_overflow firing must not re-trigger a second re-plan of
    the already-replaced plan (the free-running benchmark loop interleaving)."""
    data = _dataset()
    _, plan, re_ = _reestimator(data)
    qx, qy = _storm_batch()
    with pytest.warns(CapacityOverflowWarning):
        for _ in range(PERSISTENT_OVERFLOW_BATCHES):
            re_.execute(qx, qy)
    assert re_.join() == HEALTHY
    assert re_.plan is not plan
    re_._maybe_replan(plan)  # the stale in-flight batch's trigger call
    assert re_.state == HEALTHY  # ignored: evidence is about a replaced plan
    s = re_.stats()
    assert (s["triggers"], s["replans"], s["swaps"]) == (1, 1, 1)


def test_constructor_validation():
    data = _dataset()
    plan = _base_plan(data)
    reg = PlanRegistry()
    with pytest.raises(ValueError, match="growth"):
        CapacityReestimator(reg, "k", plan, growth=1.0)
    with pytest.raises(ValueError, match="max_retries"):
        CapacityReestimator(reg, "k", plan, max_retries=0)
    with pytest.raises(ValueError, match="backoff"):
        CapacityReestimator(reg, "k", plan, backoff=-1.0)
    dense = build_plan(*data, params=P, area=1.0, impl="tiled")
    with pytest.raises(ValueError, match="grid plan"):
        CapacityReestimator(reg, "k", dense)
