"""Train-step invariants: gradient-accumulation linearity and bitwise
determinism — the properties the fault-tolerant loop and elastic restarts
rely on."""


import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, ShapeConfig, smoke
from repro.data.synthetic import batch_for_arch
from repro.models import build_model
from repro.models import params as pm
from repro.optim import AdamWConfig, adamw_init
from repro.train import make_train_step


def _setup(accum):
    cfg = smoke(ARCHS["minitron-4b"])
    model = build_model(cfg)
    params = pm.materialize(model.spec(), jax.random.PRNGKey(0))
    shape = ShapeConfig("t", "train", 16, 4, accum_steps=accum)
    step = jax.jit(
        make_train_step(model, cfg, shape, opt=AdamWConfig(lr=1e-3, weight_decay=0.0),
                        remat=False, schedule=lambda s: 1.0)
    )
    batch = batch_for_arch(cfg, shape, 0)
    return cfg, params, step, batch


def test_grad_accumulation_linearity():
    """accum=1 and accum=2 over the SAME global batch produce the same loss
    and (to fp tolerance) the same updated parameters — the microbatch mean
    of means equals the full-batch mean for equal-sized microbatches."""
    _, params, step1, batch = _setup(1)
    _, _, step2, _ = _setup(2)
    opt = adamw_init(params)
    p1, _, m1 = step1(params, opt, batch, jnp.int32(0))
    p2, _, m2 = step2(params, adamw_init(params), batch, jnp.int32(0))
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
    np.testing.assert_allclose(float(m1["grad_norm"]), float(m2["grad_norm"]), rtol=1e-3)
    # post-Adam params: m/sqrt(v) amplifies fp noise where grad ~ 0, so the
    # elementwise tolerance is bounded by the lr (1e-3), not the grad error
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)


def test_train_step_bitwise_deterministic():
    """Identical inputs -> bitwise identical outputs (replay/restart safety)."""
    _, params, step, batch = _setup(2)
    opt = adamw_init(params)
    p1, o1, m1 = step(params, opt, batch, jnp.int32(3))
    p2, o2, m2 = step(params, opt, batch, jnp.int32(3))
    for a, b in zip(jax.tree.leaves((p1, m1)), jax.tree.leaves((p2, m2))):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_step_counter_and_lr_schedule_progress():
    _, params, step, batch = _setup(1)
    opt = adamw_init(params)
    p, opt, m0 = step(params, opt, batch, jnp.int32(0))
    p, opt, m1 = step(p, opt, batch, jnp.int32(1))
    assert int(opt["step"]) == 2
    assert float(m1["loss"]) != float(m0["loss"])  # params moved between steps
