"""Data pipeline: determinism, host-sharding consistency, elastic resize."""

import numpy as np

from repro.data import HostDataPipeline, SyntheticTokens


def test_deterministic_across_calls():
    ds = SyntheticTokens(vocab_size=100, global_batch=8, seq_len=16, seed=3)
    a = ds.global_batch_at(5)
    b = ds.global_batch_at(5)
    np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))
    c = ds.global_batch_at(6)
    assert not np.array_equal(np.asarray(a["tokens"]), np.asarray(c["tokens"]))


def test_labels_are_shifted_tokens():
    ds = SyntheticTokens(vocab_size=50, global_batch=4, seq_len=12)
    b = ds.global_batch_at(0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]), np.asarray(b["labels"][:, :-1]))


def test_host_slices_tile_the_global_batch():
    """4 hosts' slices concatenate to the global batch — and the stream is
    identical under a different host count (elastic resize safety)."""
    ds = SyntheticTokens(vocab_size=100, global_batch=8, seq_len=16, seed=1)
    full = np.asarray(ds.global_batch_at(7)["tokens"])
    got4 = np.concatenate([np.asarray(ds.host_batch_at(7, h, 4)["tokens"]) for h in range(4)])
    got2 = np.concatenate([np.asarray(ds.host_batch_at(7, h, 2)["tokens"]) for h in range(2)])
    np.testing.assert_array_equal(full, got4)
    np.testing.assert_array_equal(full, got2)


def test_pipeline_prefetch_order():
    ds = SyntheticTokens(vocab_size=100, global_batch=4, seq_len=8)
    pipe = HostDataPipeline(ds, host_id=0, num_hosts=1, prefetch=2).start(from_step=3)
    try:
        steps = [pipe.get()[0] for _ in range(4)]
        assert steps == [3, 4, 5, 6]
    finally:
        pipe.stop()
