"""Optimizer unit tests: AdamW against a literal numpy reference."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    clip_by_global_norm,
    compress_tree,
    global_norm,
    warmup_cosine,
)


def np_adamw(params, grads, m, v, step, cfg):
    out_p, out_m, out_v = {}, {}, {}
    c1 = 1 - cfg.b1**step
    c2 = 1 - cfg.b2**step
    for k in params:
        g = grads[k]
        m[k] = cfg.b1 * m[k] + (1 - cfg.b1) * g
        v[k] = cfg.b2 * v[k] + (1 - cfg.b2) * g * g
        mh, vh = m[k] / c1, v[k] / c2
        out_p[k] = params[k] - cfg.lr * (mh / (np.sqrt(vh) + cfg.eps) + cfg.weight_decay * params[k])
    return out_p, m, v


def test_adamw_matches_numpy_reference():
    rng = np.random.default_rng(0)
    cfg = AdamWConfig(lr=1e-2, weight_decay=0.01)
    params = {"a": rng.normal(size=(4, 3)).astype(np.float32), "b": rng.normal(size=(7,)).astype(np.float32)}
    jp = jax.tree.map(jnp.asarray, params)
    state = adamw_init(jp)
    npp = {k: v.copy() for k, v in params.items()}
    m = {k: np.zeros_like(v) for k, v in params.items()}
    v_ = {k: np.zeros_like(v) for k, v in params.items()}
    for step in range(1, 6):
        grads = {"a": rng.normal(size=(4, 3)).astype(np.float32), "b": rng.normal(size=(7,)).astype(np.float32)}
        jp, state = adamw_update(jax.tree.map(jnp.asarray, grads), state, jp, cfg)
        npp, m, v_ = np_adamw(npp, grads, m, v_, step, cfg)
    for k in params:
        np.testing.assert_allclose(np.asarray(jp[k]), npp[k], rtol=2e-5, atol=2e-6)
    assert int(state["step"]) == 5


def test_clip_by_global_norm():
    tree = {"a": jnp.ones((10,)) * 3.0, "b": jnp.ones((5,)) * 4.0}
    gn = float(global_norm(tree))
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - gn) < 1e-5
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    # below threshold: untouched
    same, _ = clip_by_global_norm(tree, 1e9)
    np.testing.assert_allclose(np.asarray(same["a"]), 3.0, rtol=1e-6)


def test_compress_tree_dtypes():
    tree = {"a": jnp.ones((3,), jnp.float32), "i": jnp.ones((3,), jnp.int32)}
    out = compress_tree(tree)
    assert out["a"].dtype == jnp.bfloat16
    assert out["i"].dtype == jnp.int32


def test_warmup_cosine_shape():
    assert float(warmup_cosine(jnp.int32(0), warmup=10, total=100)) > 0
    assert abs(float(warmup_cosine(jnp.int32(9), warmup=10, total=100)) - 1.0) < 1e-6
    end = float(warmup_cosine(jnp.int32(99), warmup=10, total=100))
    assert end < 0.2
