"""Unit + property tests for the AIDW mathematics (paper §2, Eq. 2-6)."""

import numpy as np
import jax.numpy as jnp
import pytest
from conftest import require_hypothesis
require_hypothesis()
from hypothesis import given, settings, strategies as st

from repro.core import (
    AIDWParams,
    aidw_reference,
    alpha_from_mu,
    fuzzy_membership,
    expected_nn_distance,
    idw_reference,
    paper_insertion_knn,
    running_k_best,
)
from conftest import make_points

HSET = settings(deadline=None, max_examples=25)


class TestAlphaMap:
    def test_knot_values(self):
        """Eq. 6 passes exactly through (0.1,a1),(0.3,a2),(0.5,a3),(0.7,a4),(0.9,a5)."""
        levels = (0.5, 1.0, 2.0, 3.0, 4.0)
        mu = jnp.array([0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 1.0])
        a = alpha_from_mu(mu, levels)
        np.testing.assert_allclose(a, [0.5, 0.5, 1.0, 2.0, 3.0, 4.0, 4.0], rtol=1e-6)

    def test_matches_eq6_piecewise(self):
        """Literal transcription of Eq. (6) (NOT the paper's CUDA listing,
        which has the a1-for-a2 typo in the 0.3-0.5 branch)."""
        a1, a2, a3, a4, a5 = 0.5, 1.0, 2.0, 3.0, 4.0

        def eq6(u):
            if u <= 0.1:
                return a1
            if u <= 0.3:
                return a1 * (1 - 5 * (u - 0.1)) + 5 * a2 * (u - 0.1)
            if u <= 0.5:
                return 5 * a3 * (u - 0.3) + a2 * (1 - 5 * (u - 0.3))
            if u <= 0.7:
                return a3 * (1 - 5 * (u - 0.5)) + 5 * a4 * (u - 0.5)
            if u <= 0.9:
                return 5 * a5 * (u - 0.7) + a4 * (1 - 5 * (u - 0.7))
            return a5

        mu = np.linspace(0, 1, 201)
        expected = np.array([eq6(u) for u in mu])
        got = alpha_from_mu(jnp.asarray(mu, jnp.float32), (a1, a2, a3, a4, a5))
        np.testing.assert_allclose(got, expected, rtol=1e-5, atol=1e-6)

    @given(st.floats(0.0, 1.0), st.floats(0.0, 1.0))
    @HSET
    def test_continuity_and_bounds(self, u, eps):
        levels = (0.5, 1.0, 2.0, 3.0, 4.0)
        a = float(alpha_from_mu(jnp.float32(u), levels))
        assert min(levels) - 1e-5 <= a <= max(levels) + 1e-5
        # piecewise-linear with max slope 5*(max gap); continuity via Lipschitz
        u2 = min(1.0, u + eps * 1e-3)
        a2 = float(alpha_from_mu(jnp.float32(u2), levels))
        assert abs(a2 - a) <= 5.1 * max(np.diff(levels)) * (u2 - u) + 1e-5

    def test_monotone_for_increasing_levels(self):
        mu = jnp.linspace(0, 1, 101)
        a = np.asarray(alpha_from_mu(mu, (0.5, 1.0, 2.0, 3.0, 4.0)))
        assert np.all(np.diff(a) >= -1e-6)


class TestFuzzyMembership:
    def test_eq5_bounds_and_endpoints(self):
        r = jnp.linspace(-1.0, 3.0, 101)
        mu = np.asarray(fuzzy_membership(r, 0.0, 2.0))
        assert np.all((mu >= 0) & (mu <= 1))
        assert mu[r <= 0].max() == 0.0
        assert mu[np.asarray(r) >= 2.0].min() == 1.0
        # midpoint: R = 1 -> mu = 0.5 - 0.5*cos(pi/2) = 0.5
        np.testing.assert_allclose(fuzzy_membership(jnp.float32(1.0), 0.0, 2.0), 0.5, atol=1e-6)

    def test_expected_nn_distance(self):
        # Eq. 2: unit square, m=400 -> 1/(2*sqrt(400)) = 0.025
        assert abs(expected_nn_distance(400, 1.0) - 0.025) < 1e-12


class TestKNN:
    @given(st.integers(1, 16), st.integers(20, 200), st.integers(0, 2**31 - 1))
    @HSET
    def test_paper_insertion_matches_sort(self, k, m, seed):
        rng = np.random.default_rng(seed)
        d = rng.random(m).astype(np.float32)
        got = paper_insertion_knn(d, k)
        np.testing.assert_array_equal(got, np.sort(d)[:k])

    @given(st.integers(1, 12), st.integers(1, 40), st.integers(0, 2**31 - 1))
    @HSET
    def test_running_k_best_matches_sort(self, k, t, seed):
        rng = np.random.default_rng(seed)
        rows = 7
        best = jnp.full((rows, k), jnp.inf)
        tiles = rng.random((3, rows, t)).astype(np.float32)
        for tile in tiles:
            best = running_k_best(best, jnp.asarray(tile))
        allv = tiles.transpose(1, 0, 2).reshape(rows, -1)
        expected = np.sort(allv, axis=1)[:, :k]
        expected = np.concatenate(
            [expected, np.full((rows, max(0, k - allv.shape[1])), np.inf, np.float32)], axis=1
        )[:, :k]
        np.testing.assert_allclose(np.asarray(best), expected, rtol=1e-6)

    def test_running_k_best_duplicate_safe(self):
        # ties must be extracted one occurrence at a time
        best = jnp.full((1, 3), jnp.inf)
        tile = jnp.array([[2.0, 1.0, 1.0, 1.0, 5.0]])
        out = np.asarray(running_k_best(best, tile))
        np.testing.assert_array_equal(out, [[1.0, 1.0, 1.0]])


class TestAIDWProperties:
    def test_convex_combination(self, points_small):
        """z_hat is a weighted average => bounded by [min z, max z]."""
        dx, dy, dz, qx, qy = points_small
        z, _ = aidw_reference(dx, dy, dz, qx, qy, AIDWParams(area=1.0))
        assert float(jnp.min(z)) >= dz.min() - 1e-5
        assert float(jnp.max(z)) <= dz.max() + 1e-5

    def test_exact_at_data_points(self, points_small):
        dx, dy, dz, qx, qy = points_small
        z, _ = aidw_reference(dx, dy, dz, dx[:32], dy[:32], AIDWParams(area=1.0))
        np.testing.assert_allclose(np.asarray(z), dz[:32], atol=1e-6)

    @given(st.integers(0, 2**31 - 1))
    @HSET
    def test_permutation_invariance(self, seed):
        dx, dy, dz, qx, qy = make_points(128, 40, seed=seed)
        rng = np.random.default_rng(seed)
        perm = rng.permutation(128)
        p = AIDWParams(k=8, area=1.0)
        z1, a1 = aidw_reference(dx, dy, dz, qx, qy, p)
        z2, a2 = aidw_reference(dx[perm], dy[perm], dz[perm], qx, qy, p)
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-5, atol=1e-6)

    @given(st.floats(-5.0, 5.0), st.floats(-5.0, 5.0))
    @HSET
    def test_translation_invariance(self, tx, ty):
        dx, dy, dz, qx, qy = make_points(128, 40, seed=11)
        p = AIDWParams(k=8, area=1.0)
        z1, a1 = aidw_reference(dx, dy, dz, qx, qy, p)
        z2, a2 = aidw_reference(dx + tx, dy + ty, dz, qx + tx, qy + ty, p)
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=2e-3, atol=2e-3)

    def test_scale_invariance_with_area(self):
        """Scaling coords by s and area by s^2 leaves R(S0), alpha, z unchanged."""
        dx, dy, dz, qx, qy = make_points(128, 40, seed=12)
        s = 7.5
        p1 = AIDWParams(k=8, area=1.0)
        p2 = AIDWParams(k=8, area=s * s)
        z1, a1 = aidw_reference(dx, dy, dz, qx, qy, p1)
        z2, a2 = aidw_reference(dx * s, dy * s, dz, qx * s, qy * s, p2)
        np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(z1), np.asarray(z2), rtol=1e-3, atol=1e-3)

    def test_reduces_to_idw_with_flat_levels(self):
        """With a1=..=a5=alpha, the adaptive map is constant => AIDW == IDW."""
        dx, dy, dz, qx, qy = make_points(200, 64, seed=13)
        p = AIDWParams(k=10, alpha_levels=(2.0,) * 5, area=1.0)
        z_aidw, alpha = aidw_reference(dx, dy, dz, qx, qy, p)
        z_idw = idw_reference(dx, dy, dz, qx, qy, 2.0)
        np.testing.assert_allclose(np.asarray(alpha), 2.0, atol=1e-6)
        np.testing.assert_allclose(np.asarray(z_aidw), np.asarray(z_idw), rtol=1e-5, atol=1e-6)

    def test_adaptive_beats_or_matches_idw_on_clustered_field(self):
        """Sanity check of the paper's premise on a clustered sample of a
        smooth field: AIDW's error is within a small factor of the best
        constant-alpha IDW (it adapts locally rather than globally)."""
        rng = np.random.default_rng(14)
        f = lambda x, y: np.sin(4 * x) * np.cos(3 * y) + 0.5 * x
        nc = 12
        centers = rng.random((nc, 2))
        pts = np.clip(centers[rng.integers(0, nc, 600)] + rng.normal(0, 0.05, (600, 2)), 0, 1)
        dx, dy = pts[:, 0].astype(np.float32), pts[:, 1].astype(np.float32)
        dz = f(dx, dy).astype(np.float32)
        qx = rng.random(300).astype(np.float32)
        qy = rng.random(300).astype(np.float32)
        truth = f(qx, qy)
        z_aidw, _ = aidw_reference(dx, dy, dz, qx, qy, AIDWParams(k=10, area=1.0))
        errs = {
            a: float(np.sqrt(np.mean((np.asarray(idw_reference(dx, dy, dz, qx, qy, a)) - truth) ** 2)))
            for a in (1.0, 2.0, 3.0, 4.0)
        }
        err_aidw = float(np.sqrt(np.mean((np.asarray(z_aidw) - truth) ** 2)))
        assert err_aidw <= 1.25 * min(errs.values()), (err_aidw, errs)


def test_accumulation_error_hierarchy():
    """EXPERIMENTS §Accuracy: serial f32 (the paper's per-thread kernel)
    >> tiled f32 (this repo) >> Kahan-tiled f32, against an f64 truth."""
    rng = np.random.default_rng(0)
    m = 102400
    d2 = (rng.random(m) ** 2 + 1e-6).astype(np.float64)
    w64 = d2**-1.5
    truth = w64.sum()
    w32 = w64.astype(np.float32)

    serial = np.float32(0)
    for v in w32:
        serial = np.float32(serial + v)
    serial_err = abs(float(serial) - truth) / truth

    tiled = np.float32(0)
    for t in w32.reshape(-1, 1024):
        tiled = np.float32(tiled + t.sum(dtype=np.float32))
    tiled_err = abs(float(tiled) - truth) / truth

    s = np.float32(0)
    c = np.float32(0)
    for t in w32.reshape(-1, 1024):
        y = np.float32(t.sum(dtype=np.float32) - c)
        tt = np.float32(s + y)
        c = np.float32((tt - s) - y)
        s = tt
    kahan_err = abs(float(s) - truth) / truth

    assert tiled_err < serial_err / 50, (tiled_err, serial_err)
    assert kahan_err < tiled_err / 2, (kahan_err, tiled_err)
