"""CI guard: fail when the suite skips more tests than the environment should.

The hypothesis-gated modules importorskip the `dev` extra; CI installs it,
so in CI the expected skip count is 0.  Without this guard, a broken
install step (or a future module that forgets the extra) silently stops the
property tests from running — exactly what happened to the 4
``require_hypothesis`` modules before PR 5 pinned it here.

Usage: python tools/check_skip_count.py <junit-xml> <max-skips>
"""

import sys
import xml.etree.ElementTree as ET


def main(report_path: str, max_skips: int) -> int:
    root = ET.parse(report_path).getroot()
    skipped = []
    for case in root.iter("testcase"):
        if case.find("skipped") is not None:
            node = case.find("skipped")
            skipped.append(
                f"{case.get('classname', '?')}::{case.get('name', '?')}"
                f"  ({node.get('message', '')})"
            )
    print(f"skipped tests: {len(skipped)} (baseline allows {max_skips})")
    for name in skipped:
        print(f"  SKIPPED {name}")
    if len(skipped) > max_skips:
        print(
            "ERROR: skip count exceeds the known-environment baseline — "
            "is the dev extra (hypothesis) installed?",
            file=sys.stderr,
        )
        return 1
    return 0


if __name__ == "__main__":
    if len(sys.argv) != 3:
        print(__doc__, file=sys.stderr)
        sys.exit(2)
    sys.exit(main(sys.argv[1], int(sys.argv[2])))
