"""Analytic TPU-v5e performance model for the AIDW kernels — the modeled-TPU
side of every benchmark table (this box is CPU-only; see EXPERIMENTS §Perf
for the roofline derivation and assumptions).

Per interpolated point, both passes sweep all m data points:
  kNN pass    : 7 flop/pair (2 sub, 2 mul, 1 add + 2 amortised compare/select)
                + k-pass merge ~ 3k flop/pair/  (vectorised min-extract,
                  amortised over d_chunk columns: 3k*(k+bm)/bm ~ 3k)
  weight pass : 7 flop/pair distance + ~8 flop/pair for exp/log weight
                (transcendentals run on the VPU at ~1 elem/cycle/lane)
HBM traffic  : SoA reads 12 B/point/tile-sweep (x,y,z f32) streamed once per
               query block; AoaS reads 16 B/point (padded struct).
"""

from __future__ import annotations

PEAK_VPU_F32 = 197e12 / 4  # v5e VPU f32 (vector) ~ 1/4 of MXU bf16 peak
HBM_BW = 819e9


def aidw_flops(m, n, k=10, layout="soa"):
    knn = (7 + 3 * k) * m * n
    weight = (7 + 8) * m * n
    return knn + weight


def aidw_hbm_bytes(m, n, k=10, layout="soa", block_q=256, impl="tiled"):
    per_point = 12 if layout == "soa" else 16
    sweeps = 2  # the paper's two distance passes
    query_blocks = max(n // block_q, 1)
    data_traffic = per_point * m * query_blocks * sweeps
    io = 8 * n + 12 * m  # queries in, z out (+ initial load)
    return data_traffic + io


def modeled_tpu_seconds(m, n, k=10, layout="soa", impl="tiled", block_q=None):
    """Roofline max(compute, memory) — collective-free on one chip.
    The naive kernel's query block is VMEM-capped at 64 (the whole data
    array must co-reside), quadrupling its data re-fetch traffic."""
    if block_q is None:
        block_q = 64 if impl == "naive" else 256
    compute = aidw_flops(m, n, k, layout) / PEAK_VPU_F32
    memory = aidw_hbm_bytes(m, n, k, layout, block_q, impl) / HBM_BW
    return max(compute, memory), {"compute_s": compute, "memory_s": memory}


def naive_vmem_bytes(m, block_q=64, k=10):
    """Working set of the UNTILED (naive) kernel: full data arrays + the
    (block_q, k+m) merge buffer resident in VMEM."""
    return 3 * 4 * m + 4 * block_q * (k + m) + 4 * block_q * 4


VMEM_BYTES = 16 * 2**20  # v5e ~16 MiB/core
