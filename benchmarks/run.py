"""Benchmark harness — one function per paper table/figure.

CSV rows: ``table,name,value,derived`` on stdout; sections mirror the paper:
  table1  — execution time (measured XLA-CPU at reduced sizes + modeled TPU
            at the paper's sizes; this box has no GPU/TPU to time)
  fig4    — speedups on single precision (modeled TPU vs measured CPU)
  fig5    — double precision (measured f64/f32 CPU ratio; TPU has no f64)
  fig6    — SoA vs AoaS (measured CPU + analytic byte ratio)
  fig7    — tiled vs naive (measured CPU locality effect + the VMEM cliff)
  lm      — roofline summary of the dry-run artifacts (if present)

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._timing import time_fn
from benchmarks.aidw_model import (
    VMEM_BYTES,
    modeled_tpu_seconds,
    naive_vmem_bytes,
)
from repro.core.aidw import AIDWParams, aidw_interpolate, brute_r_obs
from repro.core.grid import build_grid, grid_r_obs
from repro.core.idw import idw_interpolate
from repro.core.layouts import soa_to_aoas
from repro.data.spatial import clustered_points, uniform_points

K = 1024
PAPER_SIZES = {"10K": 10 * K, "50K": 50 * K, "100K": 100 * K, "500K": 500 * K, "1000K": 1000 * K}
# Paper Table 1 (ms), single precision — cited for comparison
PAPER_TABLE1 = {
    "cpu": {"10K": 6791, "50K": 168234, "100K": 673806, "500K": 16852984, "1000K": 67471402},
    "naive_soa": {"10K": 65.3, "50K": 863, "100K": 2884, "500K": 63599, "1000K": 250574},
    "tiled_soa": {"10K": 61.3, "50K": 714, "100K": 2242, "500K": 43843, "1000K": 168189},
}


def _row(table, name, value, derived=""):
    print(f"{table},{name},{value},{derived}")


def _points(m, dtype=np.float32, seed=0):
    dx, dy, dz = uniform_points(m, seed=seed, dtype=dtype)
    qx, qy, _ = uniform_points(m, seed=seed + 1, dtype=dtype)
    return map(jnp.asarray, (dx, dy, dz, qx, qy))


def table1_execution_time(quick=False):
    """Paper Table 1. Measured: XLA-CPU tiled AIDW at reduced sizes (the
    honest CPU baseline this box can run). Modeled: TPU-v5e roofline at the
    paper's sizes."""
    p = AIDWParams(k=10, area=1.0)
    sizes = [1 * K, 4 * K] if quick else [1 * K, 4 * K, 16 * K]
    for m in sizes:
        dx, dy, dz, qx, qy = _points(m)
        t = time_fn(lambda: aidw_interpolate(dx, dy, dz, qx, qy, p, area=1.0,
                                             q_chunk=min(1024, m), d_chunk=min(4096, m)))
        _row("table1", f"cpu_xla_aidw_{m//K}K", f"{t*1e3:.1f}ms", f"m=n={m}")
    for name, m in PAPER_SIZES.items():
        for impl in ("naive", "tiled"):
            sec, parts = modeled_tpu_seconds(m, m, impl=impl)
            feasible = naive_vmem_bytes(m) <= VMEM_BYTES if impl == "naive" else True
            _row("table1", f"tpu_modeled_{impl}_soa_{name}",
                 f"{sec*1e3:.1f}ms" if feasible else "VMEM-infeasible",
                 f"compute={parts['compute_s']*1e3:.1f}ms memory={parts['memory_s']*1e3:.1f}ms")
        _row("table1", f"paper_gpu_tiled_{name}", f"{PAPER_TABLE1['tiled_soa'][name]}ms", "paper value, GT 730M")


def fig4_speedups(quick=False):
    """Paper Fig. 4: speedup vs the CPU baseline, single precision.
    We report (a) the paper's own 270x/400x claims, (b) our modeled-TPU vs
    measured-CPU speedup at sizes this box can time."""
    p = AIDWParams(k=10, area=1.0)
    m = 4 * K if quick else 16 * K
    dx, dy, dz, qx, qy = _points(m)
    t_cpu = time_fn(lambda: aidw_interpolate(dx, dy, dz, qx, qy, p, area=1.0))
    t_tpu_naive, _ = modeled_tpu_seconds(m, m, impl="naive")
    t_tpu_tiled, _ = modeled_tpu_seconds(m, m, impl="tiled")
    _row("fig4", f"measured_cpu_{m//K}K", f"{t_cpu*1e3:.1f}ms")
    _row("fig4", "modeled_speedup_naive", f"{t_cpu/t_tpu_naive:.0f}x", "vs 1-core XLA-CPU")
    _row("fig4", "modeled_speedup_tiled", f"{t_cpu/t_tpu_tiled:.0f}x", "vs 1-core XLA-CPU")
    _row("fig4", "paper_speedup_naive", "270x", "paper: i7-4700MQ 1-thread vs GT 730M")
    _row("fig4", "paper_speedup_tiled", "400x", "paper")


def fig5_double_precision(quick=False):
    """Paper Fig. 5: f64 performance.  Measured f64/f32 ratio on CPU; on the
    TPU target f64 has no native unit (the paper's f64 cliff is absolute)."""
    m = 2 * K if quick else 8 * K
    script = f"""
import numpy as np, jax.numpy as jnp, time, jax
from repro.core.aidw import AIDWParams, aidw_interpolate
from repro.data.spatial import uniform_points
p = AIDWParams(k=10, area=1.0)
for dt in (np.float32, np.float64):
    dx, dy, dz = uniform_points({m}, seed=0, dtype=dt)
    qx, qy, _ = uniform_points({m}, seed=1, dtype=dt)
    args = list(map(jnp.asarray, (dx, dy, dz, qx, qy)))
    f = lambda: aidw_interpolate(*args, p, area=1.0)
    jax.block_until_ready(f())
    t0 = time.perf_counter(); jax.block_until_ready(f()); t = time.perf_counter() - t0
    print(f"F64BENCH,{{np.dtype(dt).name}},{{t*1e3:.1f}}")
"""
    env = dict(os.environ, JAX_ENABLE_X64="1", PYTHONPATH="src")
    r = subprocess.run([sys.executable, "-c", script], env=env, capture_output=True, text=True, timeout=1200)
    times = {}
    for line in r.stdout.splitlines():
        if line.startswith("F64BENCH"):
            _, name, ms = line.split(",")
            times[name] = float(ms)
            _row("fig5", f"measured_cpu_{name}_{m//K}K", f"{ms}ms")
    if "float32" in times and "float64" in times:
        _row("fig5", "measured_f64_over_f32", f"{times['float64']/times['float32']:.2f}x", "CPU (SIMD width halves)")
    _row("fig5", "paper_f64_speedup", "~8x vs CPU", "GT 730M f64 at 1/24 rate")
    _row("fig5", "tpu_f64", "no native f64", "use Kahan-f32 instead (EXPERIMENTS §Accuracy)")


def fig6_layouts(quick=False):
    """Paper Fig. 6: SoA vs AoaS.  Analytic: AoaS moves 16/12 = 1.33x the
    HBM bytes.  Measured on CPU: strided struct loads vs contiguous."""
    p = AIDWParams(k=10, area=1.0)
    m = 4 * K if quick else 16 * K
    dx, dy, dz, qx, qy = _points(m)
    t_soa = time_fn(lambda: aidw_interpolate(dx, dy, dz, qx, qy, p, area=1.0))
    data_aoas = soa_to_aoas(dx, dy, dz)

    @jax.jit
    def aoas_path(a, qx, qy):
        return aidw_interpolate(a[:, 0], a[:, 1], a[:, 2], qx, qy, p, area=1.0)

    t_aoas = time_fn(lambda: aoas_path(data_aoas, qx, qy))
    _row("fig6", f"measured_cpu_soa_{m//K}K", f"{t_soa*1e3:.1f}ms")
    _row("fig6", f"measured_cpu_aoas_{m//K}K", f"{t_aoas*1e3:.1f}ms")
    _row("fig6", "analytic_tpu_byte_ratio", "1.33x", "16B vs 12B per data point per sweep")
    _row("fig6", "paper_soa_vs_aoas", "1.015x", "paper: SoA slightly faster")


def fig7_tiled_vs_naive(quick=False):
    """Paper Fig. 7: tiled vs naive.  Measured on CPU: cache-locality effect
    of tiling (full-matrix reference vs tiled interpolate).  Analytic on
    TPU: the naive kernel's VMEM working set crosses the 16 MiB cliff."""
    from repro.core.aidw import aidw_reference

    p = AIDWParams(k=10, area=1.0)
    m = 2 * K if quick else 8 * K
    dx, dy, dz, qx, qy = _points(m)
    ref = jax.jit(lambda *a: aidw_reference(*a, p, area=1.0))
    t_naive = time_fn(lambda: ref(dx, dy, dz, qx, qy))
    t_tiled = time_fn(lambda: aidw_interpolate(dx, dy, dz, qx, qy, p, area=1.0))
    _row("fig7", f"measured_cpu_fullmatrix_{m//K}K", f"{t_naive*1e3:.1f}ms", "naive analogue: O(n*m) matrix")
    _row("fig7", f"measured_cpu_tiled_{m//K}K", f"{t_tiled*1e3:.1f}ms")
    note = ("CPU cache locality favours tiling" if t_naive > t_tiled
            else "at this size the full matrix fits cache; tiled pays scan overhead")
    _row("fig7", "measured_naive_over_tiled", f"{t_naive/t_tiled:.2f}x", note)
    for name, m_ in PAPER_SIZES.items():
        fits = naive_vmem_bytes(m_) <= VMEM_BYTES
        _row("fig7", f"tpu_naive_vmem_{name}", f"{naive_vmem_bytes(m_)/2**20:.1f}MiB",
             "fits" if fits else "exceeds 16MiB VMEM -> naive unschedulable on TPU")
    _row("fig7", "paper_tiled_speedup", "1.3x", "paper: shared-memory tiling")


def grid_plan_reuse(quick=False, smoke=False, json_path=None):
    """Plan/execute engine (DESIGN.md §6): build-once serve-many amortisation
    for ``impl="grid"``, the serving shape the engine exists for.

    Protocol (everything recorded, nothing hidden): a fresh plan is built
    (``build_plan`` — grid + CSR snapshot + required_radius table + static
    capacity), the FIRST tile-local query batch executes through the jitted
    engine (this pays the one-time trace+compile that the static-shape
    refactor makes cacheable), then further same-shape batches hit the jit
    cache.  ``reuse_speedup`` = (build + first batch) / steady batch — what a
    per-request rebuild would cost vs an amortised request.  Also exercises
    the eager (unjitted) execute and asserts eager/jit/oracle parity, and
    records the plan-time autotune decisions (candidate ``block_d``,
    capacity, rebuilds) for the ROADMAP occupancy-autotuning item.
    """
    import time as _time

    from repro.core.aidw import aidw_reference
    from repro.engine import build_plan, execute
    from repro.engine.execute import _execute

    p = AIDWParams(k=10, area=1.0)
    # --quick shrinks sizes AND (like --smoke) skips the json write, so the
    # committed full-run numbers survive the dev loop
    m = 2048 if smoke else (4 * K if quick else 20 * K)
    nq = 128 if smoke else 256
    write_json = json_path and not (smoke or quick)
    dxn, dyn, dzn = uniform_points(m, seed=0)
    dx, dy, dz = map(jnp.asarray, (dxn, dyn, dzn))
    rng = np.random.default_rng(7)

    def tile_batch():
        # a map-tile-shaped serving request: queries local to a 0.1^2 patch
        corner = rng.random(2) * 0.9
        q = (corner + 0.1 * rng.random((nq, 2))).astype(np.float32)
        return jnp.asarray(q[:, 0]), jnp.asarray(q[:, 1])

    t0 = _time.perf_counter()
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    t_build = _time.perf_counter() - t0

    qx1, qy1 = tile_batch()
    t0 = _time.perf_counter()
    z1, a1 = jax.block_until_ready(execute(plan, qx1, qy1))
    t_first = _time.perf_counter() - t0  # includes the one-time trace+compile

    t_steady = min(
        time_fn(lambda q=tile_batch(): execute(plan, *q), warmup=0, repeats=1)
        for _ in range(3)
    )

    # parity guard: eager execute, jitted execute and the oracle must agree
    z_e, _, stats = _execute(plan, qx1, qy1)
    z_ref, _ = aidw_reference(dx, dy, dz, qx1, qy1, p, area=1.0)
    err_jit = float(jnp.max(jnp.abs(z1 - z_ref)))
    err_eager = float(jnp.max(jnp.abs(z_e - z_ref)))
    assert err_jit < 1e-3 and err_eager < 1e-3, (err_jit, err_eager)

    ratio = (t_build + t_first) / t_steady
    _row("plan", f"build_{m//K}K", f"{t_build*1e3:.0f}ms",
         f"grid {plan.grid.gx}x{plan.grid.gy} rebuilds={plan.grid_rebuilds}")
    _row("plan", f"first_batch_{nq}q", f"{t_first*1e3:.0f}ms", "includes trace+compile")
    _row("plan", f"steady_batch_{nq}q", f"{t_steady*1e3:.0f}ms", "jit cache hit")
    _row("plan", "reuse_speedup", f"{ratio:.1f}x", "(build+first)/steady")
    _row("plan", "autotuned_block_d", str(plan.cand_block_d),
         f"cand_capacity={plan.cand_capacity} "
         f"overflow_queries={int(stats['overflow_queries'])}")
    _row("plan", "parity_max_abs_err", f"{max(err_jit, err_eager):.2e}", "eager+jit vs oracle")

    if write_json:
        os.makedirs(os.path.dirname(json_path), exist_ok=True)
        blob = {}
        if os.path.exists(json_path):
            with open(json_path) as f:
                blob = json.load(f)
        blob["plan_reuse"] = {
            "impl": "grid", "m": m, "nq_per_batch": nq, "k": p.k,
            "grid": f"{plan.grid.gx}x{plan.grid.gy}", "cap": plan.grid.cap,
            "autotuned_block_d": plan.cand_block_d,
            "cand_capacity": plan.cand_capacity,
            "grid_rebuilds": plan.grid_rebuilds,
            # PR-4 blend: per-query diagnostic replaces the old whole-batch
            # fallback_used flag (grid_fallback now means ALL queries overflowed)
            "overflow_queries": int(stats["overflow_queries"]),
            "build_ms": round(t_build * 1e3, 1),
            "first_batch_ms_incl_compile": round(t_first * 1e3, 1),
            "steady_batch_ms": round(t_steady * 1e3, 1),
            "reuse_speedup": round(ratio, 1),
            "max_abs_err_vs_oracle": max(err_jit, err_eager),
            "protocol": "(plan build + first batch incl jit compile) / steady "
                        "same-shape batch; tile-local serving batches",
        }
        with open(json_path, "w") as f:
            json.dump(blob, f, indent=2)
        _row("plan", "json", json_path)


def grid_phase1(quick=False, smoke=False, json_path=None):
    """Tentpole sweep: grid-partitioned vs brute-force Phase 1 (r_obs) on
    uniform and clustered data — the adaptive case the paper targets.  The
    grid row times build_grid + the ring search, so the speedup is end-to-end
    honest; JSON results land in benchmarks/results/grid_knn.json."""
    k = 10
    sizes = [2 * K] if smoke else ([20 * K] if quick else [20 * K, 100 * K])
    records = []
    for dist_name, gen in (("uniform", uniform_points), ("clustered", clustered_points)):
        for m in sizes:
            nq = max(m // 5, 1024)
            dxn, dyn, _ = gen(m, seed=0)
            qxn, qyn, _ = uniform_points(nq, seed=1)
            dx, dy, qx, qy = map(jnp.asarray, (dxn, dyn, qxn, qyn))
            # one warm+parity eval, one timed eval — the 100K brute baseline
            # is minutes per eval, so no repeats
            r_brute = jax.block_until_ready(brute_r_obs(dx, dy, qx, qy, k))
            t_brute = time_fn(lambda: brute_r_obs(dx, dy, qx, qy, k), warmup=0, repeats=1)
            grid = build_grid(dx, dy)
            r_grid = jax.block_until_ready(grid_r_obs(grid, qx, qy, k))

            def grid_pass():
                g = build_grid(dx, dy)
                return grid_r_obs(g, qx, qy, k)

            t_grid = time_fn(grid_pass, warmup=0, repeats=1)
            # parity guard: a benchmark of a wrong answer is worthless
            err = float(jnp.max(jnp.abs(r_grid - r_brute)))
            tag = f"{dist_name}_{m//K}K"
            _row("grid", f"brute_phase1_{tag}", f"{t_brute*1e3:.1f}ms", f"m={m} nq={nq} k={k}")
            _row("grid", f"grid_phase1_{tag}", f"{t_grid*1e3:.1f}ms",
                 f"build+search, {grid.gx}x{grid.gy} cells cap={grid.cap}")
            _row("grid", f"grid_speedup_{tag}", f"{t_brute/t_grid:.1f}x", f"max|dr_obs|={err:.2e}")
            records.append({
                "distribution": dist_name, "m": m, "nq": nq, "k": k,
                "grid": f"{grid.gx}x{grid.gy}", "cap": grid.cap,
                "brute_phase1_ms": round(t_brute * 1e3, 1),
                "grid_phase1_ms": round(t_grid * 1e3, 1),
                "speedup": round(t_brute / t_grid, 1),
                "max_abs_r_obs_err": err,
            })
    if json_path and not (smoke or quick):
        # full runs only: a --quick sweep would silently replace the
        # committed 100K full-sweep numbers with 20K quick rows
        os.makedirs(os.path.dirname(json_path), exist_ok=True)
        blob = {}
        if os.path.exists(json_path):
            with open(json_path) as f:
                blob = json.load(f)  # merge: keep the plan_reuse section
        blob.update(backend=jax.default_backend(), results=records)
        with open(json_path, "w") as f:
            json.dump(blob, f, indent=2)
        _row("grid", "json", json_path)


def grid_blend(quick=False, smoke=False, json_path=None):
    """Sparsity-skipping Phase 1 + per-block overflow blend (--only blend).

    Three serving-shaped scenarios against the grid plan, each parity-checked
    (eager AND jitted execute vs the exact chunked ring-search oracle):

      uniform   — full-bbox batch on uniform data: prefetch-skip vs dense
                  Phase-1 pipelines (same gather, same kernel body; the skip
                  pipeline clamps each block to its own non-sentinel tiles).
      clustered — tile-local sparse batch on clustered data: the skip
                  fraction is highest here (most blocks need few tiles).
      seam      — mostly tile-local batch plus a small full-diagonal slice
                  (straddles Morton seams, leaves the bbox, crosses empty
                  regions): a couple of blocks overflow the static capacity.
                  PR-2's whole-batch ``lax.cond`` would ring-search ALL nq
                  queries (``ring_full_ms`` is a *lower bound* on its batch
                  latency — Phase 2 comes on top); the blend ring-searches
                  only the overflowed ones (``ring_masked_ms``) and keeps
                  the kernel result everywhere else, so ``blend_exec_ms``
                  (the full batch, Phase 2 included) undercuts it.

    CPU-interpret caveat (recorded in the json): Pallas kernels here run in
    interpret mode, which makes kernel arms look *slower* relative to the
    pure-jnp ring search than they are on TPU — the blend/skip wins below
    are therefore conservative for the compiled target.
    """
    from repro.core.grid import grid_r_obs as _ring
    from repro.engine import build_plan, execute, execute_with_stats
    from repro.engine.execute import _execute

    p = AIDWParams(k=10, area=1.0)
    m = 2048 if smoke else (4 * K if quick else 20 * K)
    nq = 256 if smoke else 4096
    k = p.k
    write_json = json_path and not (smoke or quick)
    rng = np.random.default_rng(3)
    results = {}

    def timed(f):
        return time_fn(f, warmup=1, repeats=1)  # 1 warm (compile) + 1 timed eval

    def parity(plan, qx, qy, dx, dy, dz, tag):
        # eager + jitted execute vs the exact chunked ring-search oracle
        z_jit, a_jit = execute(plan, qx, qy)
        z_e, a_e, _ = _execute(plan, qx, qy)
        z_ref, a_ref = aidw_interpolate(dx, dy, dz, qx, qy, p, area=1.0,
                                        knn="grid", grid=plan.grid)
        err = max(float(jnp.max(jnp.abs(z_jit - z_ref))), float(jnp.max(jnp.abs(z_e - z_ref))),
                  float(jnp.max(jnp.abs(a_jit - a_ref))), float(jnp.max(jnp.abs(a_e - a_ref))))
        assert err < 1e-3, (tag, err)
        return err

    # ---- uniform + clustered: dense vs prefetch-skip pipelines
    for dist, gen in (("uniform", uniform_points), ("clustered", clustered_points)):
        dxn, dyn, dzn = gen(m, seed=0)
        dx, dy, dz = map(jnp.asarray, (dxn, dyn, dzn))
        if dist == "uniform":
            qn = uniform_points(nq, seed=1)
            qx, qy = jnp.asarray(qn[0]), jnp.asarray(qn[1])
        else:  # tile-local sparse batch near the data clusters
            pick = rng.integers(0, m, nq)
            qq = (np.stack([dxn, dyn], 1)[pick] + rng.normal(0, 0.01, (nq, 2))).astype(np.float32)
            qx, qy = jnp.asarray(qq[:, 0]), jnp.asarray(qq[:, 1])
        plans = {pipe: build_plan(dx, dy, dz, params=p, area=1.0, impl="grid", pipeline=pipe)
                 for pipe in ("prefetch", "dense")}
        err = parity(plans["prefetch"], qx, qy, dx, dy, dz, dist)
        _, _, stats = execute_with_stats(plans["prefetch"], qx, qy)
        t_pre = timed(lambda: execute(plans["prefetch"], qx, qy))
        t_den = timed(lambda: execute(plans["dense"], qx, qy))
        skip = float(stats["skipped_tile_fraction"])
        _row("blend", f"{dist}_dense_exec", f"{t_den*1e3:.0f}ms", f"m={m} nq={nq}")
        _row("blend", f"{dist}_prefetch_exec", f"{t_pre*1e3:.0f}ms",
             f"skipped_tile_fraction={skip:.2f}")
        _row("blend", f"{dist}_skip_speedup", f"{t_den/t_pre:.2f}x", f"parity_err={err:.1e}")
        results[dist] = {
            "dense_exec_ms": round(t_den * 1e3, 1),
            "prefetch_exec_ms": round(t_pre * 1e3, 1),
            "skipped_tile_fraction": round(skip, 3),
            "overflow_queries": int(stats["overflow_queries"]),
            "parity_max_abs_err": err,
        }

    # ---- seam: the overflow worst case, cond-fallback vs per-block blend
    dxn, dyn, dzn = clustered_points(m, seed=0)
    dx, dy, dz = map(jnp.asarray, (dxn, dyn, dzn))
    n_far = max(nq // 16, 16)
    pick = rng.integers(0, m, nq - n_far)
    near = (np.stack([dxn, dyn], 1)[pick] + rng.normal(0, 0.01, (nq - n_far, 2))).astype(np.float32)
    t = np.linspace(-0.2, 1.2, n_far).astype(np.float32)
    q = np.concatenate([near, np.stack([t, t], 1)])
    rng.shuffle(q)
    qx, qy = jnp.asarray(q[:, 0]), jnp.asarray(q[:, 1])
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    plan0 = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid", seam_level=0)
    err = parity(plan, qx, qy, dx, dy, dz, "seam")
    _, _, stats = execute_with_stats(plan, qx, qy)
    _, _, stats0 = execute_with_stats(plan0, qx, qy)
    mask = stats["overflow_query_mask"]
    t_blend = timed(lambda: execute(plan, qx, qy))
    t_full = timed(lambda: _ring(plan.grid, qx, qy, k))
    t_masked = timed(lambda: _ring(plan.grid, qx, qy, k, mask))
    ovf = int(stats["overflow_queries"])
    _row("blend", "seam_overflow_queries", str(ovf),
         f"of {nq}; seam_level={plan.seam_level} (vs {int(stats0['overflow_queries'])} unsplit)")
    _row("blend", "seam_blend_exec", f"{t_blend*1e3:.0f}ms", "full batch incl. Phase 2")
    _row("blend", "seam_ring_full", f"{t_full*1e3:.0f}ms",
         "PR-2 cond arm: ring search for ALL queries (lower bound, no Phase 2)")
    _row("blend", "seam_ring_masked", f"{t_masked*1e3:.0f}ms", "blend arm: overflowed queries only")
    _row("blend", "seam_worst_case_speedup", f"{t_full/t_blend:.1f}x",
         "whole-batch ring arm vs full blended batch"
         + ("" if t_blend < t_full else " [WARNING: blend did not undercut it]"))
    results["seam"] = {
        "overflow_queries": ovf,
        "overflow_blocks": int(stats["overflow_blocks"]),
        "overflow_queries_seam_level_0": int(stats0["overflow_queries"]),
        "seam_level": plan.seam_level,
        "blend_exec_ms": round(t_blend * 1e3, 1),
        "ring_full_ms_pr2_lower_bound": round(t_full * 1e3, 1),
        "ring_masked_ms": round(t_masked * 1e3, 1),
        "skipped_tile_fraction": round(float(stats["skipped_tile_fraction"]), 3),
        "parity_max_abs_err": err,
    }

    if write_json:
        os.makedirs(os.path.dirname(json_path), exist_ok=True)
        blob = {
            "backend": jax.default_backend(),
            "mode": "Pallas kernels in interpret mode on CPU (kernel arms are "
                    "emulated — slower relative to the pure-jnp ring search than "
                    "on TPU, so blend/skip speedups are conservative)",
            "m": m, "nq": nq, "k": k,
            "scenarios": results,
            "protocol": "jitted execute, steady state (1 warm + 1 timed eval); "
                        "ring_full is PR-2's whole-batch lax.cond exact arm (its "
                        "batch latency lower bound); blend_exec is the shipped "
                        "path end to end; dense vs prefetch differ only in the "
                        "Phase-1 pipeline (same gather, same kernel body).",
        }
        with open(json_path, "w") as f:
            json.dump(blob, f, indent=2)
        _row("blend", "json", json_path)


def farfield_phase2(quick=False, smoke=False, json_path=None):
    """Far-field approximated Phase 2 vs the exact full sweep (--only farfield).

    The ROADMAP O(n*m) wall: Phase 2 weights ALL m points per query in every
    exact impl.  ``build_plan(phase2="farfield")`` sweeps exact weights only
    over each block's near rectangle and folds one aggregate term per far
    cell (DESIGN.md §7).  Protocol: uniform m-point dataset, a tile-local
    serving batch (the shape the capacity model sizes for); the two Phase-2
    paths are timed IN ISOLATION on identical inputs (same Morton-sorted
    padded queries, same exact Phase-1 alpha — so the ratio is purely the
    Phase-2 algorithm change), plus end-to-end execute times for context.
    Accuracy is measured against the Kahan oracle (farfield_error_report)
    and asserted within the plan's proved worst-case bound; requested rtol,
    proved bound and measured error are all recorded — single-level
    aggregates prove weak worst-case bounds (the plan warns), measured
    error runs orders of magnitude below them.

    CPU-interpret caveat (as grid_blend): kernel arms are emulated; the
    speedup is a step-count effect and is conservative vs compiled TPU.
    """
    import functools as _ft
    import warnings as _warnings

    from repro.core.accuracy import farfield_error_report
    from repro.core.grid import cell_of, morton_ids
    from repro.core.layouts import pad_tail
    from repro.engine import build_plan, execute, execute_with_stats
    from repro.engine.execute import _phase2_farfield
    from repro.kernels.aidw_grid import phase2_weights_full

    p = AIDWParams(k=10, area=1.0)
    m = 2048 if smoke else (20 * K if quick else 100 * K)
    nq = 256 if smoke else 4096
    rtol = 1e-3
    write_json = json_path and not (smoke or quick)
    rng = np.random.default_rng(11)
    dxn, dyn, dzn = uniform_points(m, seed=0)
    dx, dy, dz = map(jnp.asarray, (dxn, dyn, dzn))
    corner = rng.random(2) * 0.85
    q = (corner + 0.12 * rng.random((nq, 2))).astype(np.float32)
    qx, qy = jnp.asarray(q[:, 0]), jnp.asarray(q[:, 1])

    with _warnings.catch_warnings():
        _warnings.simplefilter("ignore")  # unprovable-rtol warning: recorded below
        plan_ff = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                             phase2="farfield", farfield_rtol=rtol, block_q=64)
    # the chooser meets the target exactly when its proved bound does
    rtol_provable = plan_ff.farfield_bound <= rtol
    plan_ex = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid", block_q=64)

    def timed(f):
        return time_fn(f, warmup=1, repeats=1)

    # identical Phase-2 inputs for both arms: sorted/padded batch + exact alpha
    cx, cy = cell_of(plan_ff.grid, qx, qy)
    order = jnp.argsort(morton_ids(cx, cy), stable=True)
    n_pad = (-nq) % plan_ff.block_q
    qx_s = pad_tail(qx[order], n_pad)
    qy_s = pad_tail(qy[order], n_pad)
    _, alpha = execute(plan_ex, qx, qy)
    alpha_s = pad_tail(alpha[order], n_pad)[:, None]

    p2_ff = jax.jit(lambda pl_, a, b, c: _phase2_farfield(pl_, a, b, c)[0])
    dxp, dyp, dzp = plan_ex.data
    p2_ex = jax.jit(_ft.partial(
        phase2_weights_full, eps=p.exact_hit_eps, block_q=plan_ex.block_q,
        block_d=plan_ex.block_d, interpret=plan_ex.interpret))
    t_p2_ex = timed(lambda: p2_ex(qx_s, qy_s, alpha_s, dxp, dyp, dzp))
    t_p2_ff = timed(lambda: p2_ff(plan_ff, qx_s, qy_s, alpha_s))
    t_e2e_ex = timed(lambda: execute(plan_ex, qx, qy))
    t_e2e_ff = timed(lambda: execute(plan_ff, qx, qy))

    _, _, stats = execute_with_stats(plan_ff, qx, qy)
    if int(stats["p2_overflow_queries"]) > 0:
        _row("farfield", "WARNING", "near-capacity overflow",
             "batch partly fell back to the exact sweep")
    rep = farfield_error_report(plan_ff, qx, qy)
    assert rep["within_bound"], rep  # a benchmark of a broken budget is worthless
    # the smoke config proves no useful bound (inf), which would make the
    # assert above vacuous in CI — also gate on an empirical sanity ceiling
    # so a far-kernel regression fails the bench-smoke job too
    assert rep["max_rel_err"] <= 10 * rtol, rep
    speedup = t_p2_ex / t_p2_ff
    tag = f"{m//K}K"
    _row("farfield", f"phase2_exact_{tag}", f"{t_p2_ex*1e3:.0f}ms",
         f"nq={nq} full {m}-point sweep")
    _row("farfield", f"phase2_farfield_{tag}", f"{t_p2_ff*1e3:.0f}ms",
         f"radius={plan_ff.farfield_radius} near_mean={float(stats['near_points_mean']):.0f} "
         f"far_cells_mean={float(stats['far_cells_mean']):.0f}")
    _row("farfield", "phase2_speedup", f"{speedup:.1f}x",
         "isolated Phase 2, identical inputs"
         + ("" if speedup >= 3 or smoke or quick else " [WARNING: below 3x target]"))
    _row("farfield", "e2e_exact_vs_farfield",
         f"{t_e2e_ex*1e3:.0f}ms vs {t_e2e_ff*1e3:.0f}ms", "execute() incl. Phase 1")
    _row("farfield", "measured_max_rel_err", f"{rep['max_rel_err']:.2e}",
         f"requested rtol={rtol:g} proved bound={plan_ff.farfield_bound:.3g}")

    if write_json:
        os.makedirs(os.path.dirname(json_path), exist_ok=True)
        blob = {
            "backend": jax.default_backend(),
            "mode": "Pallas kernels in interpret mode on CPU (step-count "
                    "effect; conservative vs compiled TPU)",
            "m": m, "nq": nq, "k": p.k, "block_q": plan_ff.block_q,
            "grid": f"{plan_ff.grid.gx}x{plan_ff.grid.gy}",
            "farfield_rtol_requested": rtol,
            "farfield_rtol_provable_at_profitable_radius": rtol_provable,
            "farfield_radius": plan_ff.farfield_radius,
            "farfield_bound_proved": plan_ff.farfield_bound,
            "measured_max_rel_err": rep["max_rel_err"],
            "measured_rms_rel_err": rep["rms_rel_err"],
            "near_points_mean": float(stats["near_points_mean"]),
            "far_cells_mean": float(stats["far_cells_mean"]),
            "p2_capacity": plan_ff.p2_capacity,
            "phase2_exact_ms": round(t_p2_ex * 1e3, 1),
            "phase2_farfield_ms": round(t_p2_ff * 1e3, 1),
            "phase2_speedup": round(speedup, 2),
            "e2e_exact_ms": round(t_e2e_ex * 1e3, 1),
            "e2e_farfield_ms": round(t_e2e_ff * 1e3, 1),
            "protocol": "isolated Phase-2 arms jitted and timed on identical "
                        "Morton-sorted padded queries + exact Phase-1 alpha "
                        "(1 warm + 1 timed eval); error vs Kahan oracle on "
                        "the same tile-local serving batch, asserted within "
                        "the plan's proved worst-case bound",
        }
        with open(json_path, "w") as f:
            json.dump(blob, f, indent=2)
        _row("farfield", "json", json_path)


def quadtree_phase2(quick=False, smoke=False, json_path=None):
    """Multi-level quadtree Phase 2 vs the single-level far field and the
    exact sweep (--only quadtree).

    Two protocols (both recorded in the json):

    head-to-head at m=100K — sub-cell-clustered site data (the plan-chosen
    configuration where the dipole bound PROVES rtol=1e-3; the single-level
    model cannot prove it at any profitable radius), tile-local serving
    batch (the shape the capacity model sizes for — a full-bbox Morton
    batch straddles seams and overflows the near capacity).  The Phase-2
    arms (exact full sweep / single-level farfield at its own radius AND
    at the quadtree's radius / quadtree) are jitted and timed IN ISOLATION
    on identical Morton-sorted padded queries and identical exact Phase-1
    alpha.  The matched-radius pair is the algorithmic comparison (same
    exact near field, far field = all cells vs closed nodes); the
    own-radius pair is the shipped-plan comparison.  Eager vs jitted
    quadtree execute parity is asserted, and measured error vs the Kahan
    oracle is asserted within the proved bound.

    m-sweep 10K -> 1M — uniform data at a PINNED radius (provability not
    required here; the claim under test is WORK scaling, and the auto
    chooser's profitability-cap radius growing with m would conflate
    radius policy with level scaling), recording ``far_cells_mean`` (far
    TERMS per query: closed nodes for the quadtree; the single-level
    arm's count is ~n_cells ~ O(m)).  The quadtree's far-term count must
    grow sub-linearly (~O(log m)) while cells grow ~linearly — asserted
    as far-term growth <= sqrt(cells growth) across the sweep.

    CPU-interpret caveat (as farfield_phase2): kernel arms are emulated;
    speedups are step-count effects and conservative vs compiled TPU.
    """
    import functools as _ft
    import warnings as _warnings

    from repro.core.accuracy import farfield_error_report
    from repro.core.grid import cell_of, morton_ids
    from repro.core.layouts import pad_tail
    from repro.engine import build_plan, execute, execute_with_stats
    from repro.engine.execute import _execute, _phase2_farfield, _phase2_quadtree
    from repro.kernels.aidw_grid import phase2_weights_full

    p = AIDWParams(k=10, area=1.0)
    rtol = 1e-3
    write_json = json_path and not (smoke or quick)

    def timed(f):
        return time_fn(f, warmup=1, repeats=1)

    def site_points(m, n_side, sigma, seed=5):
        # z varies INSIDE each tight spatial cluster: first-order poison for
        # the single-level bound, second-order (harmless) for the dipole one
        rng = np.random.default_rng(seed)
        sites = (np.stack(np.meshgrid(np.arange(n_side), np.arange(n_side)), -1)
                 .reshape(-1, 2) + 0.5) / n_side
        pts = (sites[rng.integers(0, n_side * n_side, m)]
               + rng.normal(0, sigma, (m, 2)))
        pts = np.clip(pts, 0.0, 1.0).astype(np.float32)
        x, y = pts[:, 0], pts[:, 1]
        z = (np.sin(6 * x) * np.cos(6 * y) + 2.0
             + 0.3 * rng.standard_normal(m)).astype(np.float32)
        return x, y, z

    # ---- head-to-head at the provable configuration
    if smoke:
        m, gx, n_side, sigma, nq = 2048, 12, 12, 1e-4, 256
    elif quick:
        m, gx, n_side, sigma, nq = 20 * K, 32, 16, 5e-5, 1024
    else:
        m, gx, n_side, sigma, nq = 100 * K, 64, 16, 2e-5, 4096
    dxn, dyn, dzn = site_points(m, n_side, sigma)
    dx, dy, dz = map(jnp.asarray, (dxn, dyn, dzn))
    rng = np.random.default_rng(11)
    corner = rng.random(2) * 0.85
    q = (corner + 0.12 * rng.random((nq, 2))).astype(np.float32)
    qx, qy = jnp.asarray(q[:, 0]), jnp.asarray(q[:, 1])
    grid = build_grid(dx, dy, dz, gx=gx, gy=gx)
    qocc = max(nq / (0.12 * gx) ** 2, 0.5)  # tile-local serving density
    with _warnings.catch_warnings():
        _warnings.simplefilter("ignore")
        plan_qt = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                             grid=grid, phase2="quadtree", farfield_rtol=rtol,
                             block_q=64, query_occupancy=qocc)
        plan_ff = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                             grid=grid, phase2="farfield", farfield_rtol=rtol,
                             block_q=64, query_occupancy=qocc)
        plan_ffm = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                              grid=grid, phase2="farfield", block_q=64,
                              farfield_radius=plan_qt.farfield_radius,
                              query_occupancy=qocc)
        plan_ex = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid",
                             grid=grid, block_q=64, query_occupancy=qocc)
    qt_provable = plan_qt.farfield_bound <= rtol
    if not smoke:
        assert qt_provable, ("head-to-head config must be provable",
                             plan_qt.farfield_bound)

    # identical Phase-2 inputs for all three arms
    cx, cy = cell_of(grid, qx, qy)
    order = jnp.argsort(morton_ids(cx, cy), stable=True)
    n_pad = (-nq) % plan_qt.block_q
    qx_s = pad_tail(qx[order], n_pad)
    qy_s = pad_tail(qy[order], n_pad)
    _, alpha = execute(plan_ex, qx, qy)
    alpha_s = pad_tail(alpha[order], n_pad)[:, None]

    dxp, dyp, dzp = plan_ex.data
    p2_ex = jax.jit(_ft.partial(
        phase2_weights_full, eps=p.exact_hit_eps, block_q=plan_ex.block_q,
        block_d=plan_ex.block_d, interpret=plan_ex.interpret))
    p2_ff = jax.jit(lambda pl_, a, b, c: _phase2_farfield(pl_, a, b, c)[0])
    p2_qt = jax.jit(lambda pl_, a, b, c: _phase2_quadtree(pl_, a, b, c)[0])
    t_ex = timed(lambda: p2_ex(qx_s, qy_s, alpha_s, dxp, dyp, dzp))
    t_ff = timed(lambda: p2_ff(plan_ff, qx_s, qy_s, alpha_s))
    t_ffm = timed(lambda: p2_ff(plan_ffm, qx_s, qy_s, alpha_s))
    t_qt = timed(lambda: p2_qt(plan_qt, qx_s, qy_s, alpha_s))

    # eager/jit parity on the shipped end-to-end path
    z_jit, a_jit = execute(plan_qt, qx, qy)
    z_eag, a_eag, stats = _execute(plan_qt, qx, qy)
    par = max(float(jnp.max(jnp.abs(z_jit - z_eag))),
              float(jnp.max(jnp.abs(a_jit - a_eag))))
    assert par < 1e-5, ("eager/jit parity", par)
    ovf = int(stats["p2_overflow_queries"])
    if ovf > 0:
        _row("quadtree", "WARNING", "near-capacity overflow",
             f"{ovf} queries fell back to the exact sweep")
    assert smoke or quick or ovf == 0, (
        "committed head-to-head must be a clean fast-path batch", ovf)
    _, _, stats_ff = execute_with_stats(plan_ff, qx, qy)
    rep = farfield_error_report(plan_qt, qx, qy)
    assert rep["within_bound"], rep
    assert rep["max_rel_err"] <= 10 * rtol, rep  # empirical ceiling for smoke

    tag = f"{m//K}K"
    vs_ff = t_ff / t_qt
    vs_ffm = t_ffm / t_qt
    _row("quadtree", f"phase2_exact_{tag}", f"{t_ex*1e3:.0f}ms",
         f"nq={nq} full {m}-point sweep")
    _row("quadtree", f"phase2_farfield_{tag}", f"{t_ff*1e3:.0f}ms",
         f"own radius={plan_ff.farfield_radius} "
         f"far_cells_mean={float(stats_ff['far_cells_mean']):.0f} "
         f"proved_bound={plan_ff.farfield_bound:.3g}")
    _row("quadtree", f"phase2_farfield_matched_{tag}", f"{t_ffm*1e3:.0f}ms",
         f"quadtree's radius={plan_ffm.farfield_radius} (same exact near "
         f"field) proved_bound={plan_ffm.farfield_bound:.3g}")
    _row("quadtree", f"phase2_quadtree_{tag}", f"{t_qt*1e3:.0f}ms",
         f"radius={plan_qt.farfield_radius} levels={len(plan_qt.qt_levels)} "
         f"far_nodes_mean={float(stats['far_cells_mean']):.0f} "
         f"proved_bound={plan_qt.farfield_bound:.3g}")
    _row("quadtree", "quadtree_vs_farfield_matched", f"{vs_ffm:.2f}x",
         "same near field; far field all-cells vs closed nodes"
         + ("" if vs_ffm >= 1 or smoke or quick
            else " [WARNING: quadtree slower at matched radius]"))
    _row("quadtree", "quadtree_vs_farfield_own", f"{vs_ff:.2f}x",
         f"shipped plans (farfield's own radius proves only "
         f"{plan_ff.farfield_bound:.3g})")
    _row("quadtree", "quadtree_vs_exact", f"{t_ex/t_qt:.1f}x")
    _row("quadtree", "measured_max_rel_err", f"{rep['max_rel_err']:.2e}",
         f"requested rtol={rtol:g} proved_bound={plan_qt.farfield_bound:.3g} "
         f"provable={qt_provable}")
    _row("quadtree", "opened_fraction", f"{float(stats['opened_fraction']):.3f}",
         f"cells_per_level={[round(float(c), 1) for c in stats['cells_per_level']]}")

    # ---- m-sweep: far terms per query must grow ~O(log m), not O(m)
    sweep_sizes = ([2 * K] if smoke else
                   [10 * K, 50 * K] if quick else
                   [10 * K, 100 * K, 1000 * K])
    sweep = []
    sweep_radius = 2  # pinned: the sweep measures level scaling, not policy
    for m_ in sweep_sizes:
        dxn, dyn, dzn = uniform_points(m_, seed=0)
        dxs, dys, dzs = map(jnp.asarray, (dxn, dyn, dzn))
        nq_s = 256
        qs_ = (rng.random(2) * 0.85
               + 0.12 * rng.random((nq_s, 2))).astype(np.float32)
        qxs, qys = jnp.asarray(qs_[:, 0]), jnp.asarray(qs_[:, 1])
        g_ = build_grid(dxs, dys, dzs)
        with _warnings.catch_warnings():
            _warnings.simplefilter("ignore")  # uniform data: honest bound
            pl_ = build_plan(dxn, dyn, dzn, params=p, area=1.0, impl="grid",
                             grid=g_, phase2="quadtree", block_q=64,
                             farfield_radius=sweep_radius,
                             query_occupancy=max(nq_s / (0.12 * g_.gx) ** 2,
                                                 0.5))
        _, _, st = execute_with_stats(pl_, qxs, qys)
        rec = {
            "m": m_, "grid": f"{g_.gx}x{g_.gy}", "n_cells": g_.n_cells,
            "levels": len(pl_.qt_levels),
            "radius": pl_.farfield_radius,
            "far_terms_mean": round(float(st["far_cells_mean"]), 1),
            "near_points_mean": round(float(st["near_points_mean"]), 1),
            "opened_fraction": round(float(st["opened_fraction"]), 3),
        }
        sweep.append(rec)
        _row("quadtree", f"sweep_far_terms_{m_//K}K", str(rec["far_terms_mean"]),
             f"n_cells={rec['n_cells']} levels={rec['levels']}")
    if len(sweep) > 1:
        cells_growth = sweep[-1]["n_cells"] / sweep[0]["n_cells"]
        work_growth = (sweep[-1]["far_terms_mean"]
                       / max(sweep[0]["far_terms_mean"], 1.0))
        _row("quadtree", "sweep_sublinear",
             f"far_terms x{work_growth:.1f} while cells x{cells_growth:.1f}",
             "quadtree far work must not track cell count")
        assert work_growth <= max(np.sqrt(cells_growth), 2.0), (
            "far-term growth is not sub-linear in cell count", sweep)

    if write_json:
        os.makedirs(os.path.dirname(json_path), exist_ok=True)
        blob = {
            "backend": jax.default_backend(),
            "mode": "Pallas kernels in interpret mode on CPU (step-count "
                    "effect; conservative vs compiled TPU)",
            "head_to_head": {
                "m": m, "nq": nq, "k": p.k, "grid": f"{gx}x{gx}",
                "block_q": plan_qt.block_q,
                "data": f"{n_side}x{n_side} sites, sigma={sigma:g}, "
                        "z noise 0.3 inside clusters",
                "farfield_rtol_requested": rtol,
                "quadtree_bound_proved": plan_qt.farfield_bound,
                "quadtree_provable": qt_provable,
                "farfield_bound_proved": plan_ff.farfield_bound,
                "farfield_provable": plan_ff.farfield_bound <= rtol,
                "quadtree_radius": plan_qt.farfield_radius,
                "quadtree_levels": len(plan_qt.qt_levels),
                "farfield_radius_own": plan_ff.farfield_radius,
                "measured_max_rel_err": rep["max_rel_err"],
                "far_nodes_mean_quadtree": float(stats["far_cells_mean"]),
                "far_cells_mean_farfield": float(stats_ff["far_cells_mean"]),
                "cells_per_level": [float(c) for c in stats["cells_per_level"]],
                "opened_fraction": float(stats["opened_fraction"]),
                "p2_overflow_queries": ovf,
                "phase2_exact_ms": round(t_ex * 1e3, 1),
                "phase2_farfield_own_radius_ms": round(t_ff * 1e3, 1),
                "phase2_farfield_matched_radius_ms": round(t_ffm * 1e3, 1),
                "phase2_quadtree_ms": round(t_qt * 1e3, 1),
                "quadtree_vs_farfield_matched_speedup": round(vs_ffm, 2),
                "quadtree_vs_farfield_own_speedup": round(vs_ff, 2),
                "quadtree_vs_exact_speedup": round(t_ex / t_qt, 2),
                "eager_jit_parity_max_abs_err": par,
            },
            "m_sweep": sweep,
            "m_sweep_radius_pinned": sweep_radius,
            "protocol": "head-to-head: Phase-2 arms jitted and timed in "
                        "isolation on identical Morton-sorted padded "
                        "tile-local queries + exact Phase-1 alpha (1 warm + "
                        "1 timed eval) at the provable site-clustered "
                        "config; matched-radius farfield shares the "
                        "quadtree's exact near field so that pair isolates "
                        "the far-field algorithm; error vs Kahan oracle "
                        "asserted within the proved dipole bound; m-sweep: "
                        "uniform data, radius pinned, far terms per query "
                        "from execute_with_stats, growth asserted sub-linear "
                        "in cell count",
        }
        with open(json_path, "w") as f:
            json.dump(blob, f, indent=2)
        _row("quadtree", "json", json_path)


def reestimator_heal(quick=False, smoke=False, json_path=None):
    """Self-healing serving loop (--only reestimator): a persistent-overflow
    storm drives the capacity re-estimator's background re-plan + atomic
    hot-swap (DESIGN.md §9).  The serving config is the known-overflow shape
    of tests/serving: a dense assumed query_occupancy undersizes the static
    candidate capacity, so every out-of-bbox batch overflows and the streak
    trigger fires after PERSISTENT_OVERFLOW_BATCHES batches.

    Measured per warmup variant (the registry can execute a warmup batch on
    the new plan BEFORE publishing it, keeping the jit compile off the
    serving thread): batches-to-recovery after the trigger,
    ``overflow_queries`` before/after the swap, and the p99 serving-batch
    latency during the re-plan window vs the steady post-swap latency —
    the swap stall.  Correctness is not re-proved here (the bitwise
    recovery proof lives in tests/serving/test_reestimator.py); the bench
    asserts only that recovery happens and overflow drops to zero.

    CPU-interpret caveat (as grid_blend): absolute latencies are emulated
    kernels; the warmup-on/off CONTRAST is the portable result.
    """
    import time as _time
    import warnings as _warnings

    from repro.engine import build_plan
    from repro.engine.execute import PERSISTENT_OVERFLOW_BATCHES
    from repro.serving import CapacityReestimator, PlanRegistry

    p = AIDWParams(k=10, area=1.0, r_max=64.0)
    m, nq = 4 * K, 64
    write_json = json_path and not (smoke or quick)
    # generous: recovery-in-batches here is wall-clock (the background build
    # competes for the GIL under CPU interpret), not the bounded-batch proof
    # — that one is join()-synchronised in tests/serving/test_reestimator.py
    max_batches = 40 * PERSISTENT_OVERFLOW_BATCHES
    rng = np.random.default_rng(13)
    dxn, dyn, dzn = uniform_points(m, seed=0)
    storm = (jnp.asarray((rng.random(nq) * 6 - 3).astype(np.float32)),
             jnp.asarray((rng.random(nq) * 6 - 3).astype(np.float32)))
    clean = (jnp.asarray((0.4 + 0.05 * rng.random(nq)).astype(np.float32)),
             jnp.asarray((0.4 + 0.05 * rng.random(nq)).astype(np.float32)))

    def heal_run(warmup):
        plan = build_plan(dxn, dyn, dzn, params=p, area=1.0, impl="grid",
                          query_occupancy=64.0)
        reg = PlanRegistry()
        re_ = CapacityReestimator(reg, "bench", plan, backoff=0.01,
                                  warmup=warmup)
        cap_before = plan.cand_capacity
        re_.execute(*storm)   # compile the batch shape on the old plan
        re_.execute(*clean)   # reset the streak the compile batch started
        lat, ovf = [], []
        trigger = recovered = None
        for i in range(1, max_batches + 1):
            t0 = _time.perf_counter()
            _, _, st = re_.execute(*storm)
            n = int(st["overflow_queries"])
            lat.append((_time.perf_counter() - t0) * 1e3)
            ovf.append(n)
            if trigger is None and bool(st["persistent_overflow"]):
                trigger = i
            if trigger is not None and n == 0:
                recovered = i
                break
        re_.join()
        assert trigger is not None and recovered is not None, (trigger, ovf)
        assert ovf[trigger - 1] > 0 and ovf[recovered - 1] == 0
        steady = [time_fn(lambda: re_.execute(*storm)[0], warmup=0, repeats=1)
                  * 1e3 for _ in range(3)]
        during = lat[trigger - 1:recovered]
        return {
            "trigger_batch": trigger,
            "batches_to_recovery": recovered - trigger,
            "overflow_queries_before_swap": ovf[trigger - 1],
            "overflow_queries_after_swap": ovf[recovered - 1],
            "cand_capacity_before": cap_before,
            "cand_capacity_after": re_.plan.cand_capacity,
            "swap_stall_p99_ms": round(float(np.percentile(during, 99)), 1),
            "steady_batch_ms": round(float(np.median(steady)), 1),
            "reestimator": re_.stats(),
        }

    variants = {"warmup": storm} if smoke or quick else \
        {"no_warmup": None, "warmup": storm}
    results = {}
    with _warnings.catch_warnings():
        _warnings.simplefilter("ignore")  # the storm's overflow warning
        for name, warmup in variants.items():
            r = heal_run(warmup)
            results[name] = r
            assert r["reestimator"]["state"] == "healthy", r
            _row("reestimator", f"{name}_batches_to_recovery",
                 str(r["batches_to_recovery"]),
                 f"trigger at batch {r['trigger_batch']} "
                 f"(threshold={PERSISTENT_OVERFLOW_BATCHES})")
            _row("reestimator", f"{name}_overflow_before_after",
                 f"{r['overflow_queries_before_swap']} -> "
                 f"{r['overflow_queries_after_swap']}",
                 f"of {nq}; cand_capacity {r['cand_capacity_before']} -> "
                 f"{r['cand_capacity_after']}")
            _row("reestimator", f"{name}_swap_stall_p99",
                 f"{r['swap_stall_p99_ms']:.0f}ms",
                 f"steady post-swap batch {r['steady_batch_ms']:.0f}ms")
    if len(results) == 2:
        _row("reestimator", "warmup_stall_reduction",
             f"{results['no_warmup']['swap_stall_p99_ms'] / max(results['warmup']['swap_stall_p99_ms'], 1e-9):.1f}x",
             "warmup-before-publish keeps the new plan's compile off the serving thread")

    if write_json:
        os.makedirs(os.path.dirname(json_path), exist_ok=True)
        blob = {
            "backend": jax.default_backend(),
            "mode": "Pallas kernels in interpret mode on CPU (absolute "
                    "latencies emulated; the warmup contrast is the "
                    "portable result)",
            "m": m, "nq_per_batch": nq, "k": p.k,
            "persistent_overflow_batches": PERSISTENT_OVERFLOW_BATCHES,
            "variants": results,
            "protocol": "out-of-bbox storm batches against a plan whose "
                        "capacity model assumed query_occupancy=64; per-batch "
                        "wall latency on the serving thread; stall window = "
                        "batches from streak trigger to first zero-overflow "
                        "batch; bitwise recovery proof lives in "
                        "tests/serving/test_reestimator.py",
        }
        with open(json_path, "w") as f:
            json.dump(blob, f, indent=2)
        _row("reestimator", "json", json_path)


def lm_rooflines(quick=False):
    """Roofline summary from the dry-run artifacts (EXPERIMENTS §Roofline)."""
    art = os.path.join(os.path.dirname(__file__), "..", "artifacts")
    cm_dir = os.path.join(art, "costmodel")
    dr_dir = os.path.join(art, "dryrun")
    if not os.path.isdir(dr_dir):
        _row("lm", "dryrun_artifacts", "missing", "run repro.launch.dryrun first")
        return
    import json
    from repro.launch.roofline import PEAK_FLOPS, HBM_BW, ICI_BW

    n = 0
    for f in sorted(os.listdir(dr_dir)):
        if not f.endswith(".json") or f.count("__") > 2:
            continue  # tagged §Perf variants are reported in EXPERIMENTS.md
        rec = json.load(open(os.path.join(dr_dir, f)))
        if rec.get("status") != "ok":
            continue
        cm_path = os.path.join(cm_dir, f)
        flops = rec.get("cost_analysis", {}).get("flops", 0)
        byts = rec.get("cost_analysis", {}).get("bytes accessed", 0)
        coll = rec.get("collectives", {}).get("total_bytes", 0)
        src = "raw"
        if os.path.exists(cm_path):
            cm = json.load(open(cm_path))
            if cm.get("status") == "ok":
                flops = cm["corrected"]["flops"]
                byts = cm["corrected"]["bytes_accessed"]
                coll = cm["corrected"]["collectives"]["total_bytes"]
                src = "loop-corrected"
        terms = {"compute": flops / PEAK_FLOPS, "memory": byts / HBM_BW, "collective": coll / ICI_BW}
        dom = max(terms, key=terms.get)
        _row("lm", f"{rec['arch']}|{rec['shape']}|{rec['mesh']}",
             f"{terms[dom]*1e3:.1f}ms", f"dominant={dom} ({src})")
        n += 1
    _row("lm", "cells_ok", str(n))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizes: tiny inputs, no json writes (implies --quick)")
    ap.add_argument("--only", default=None, help="comma-separated table names")
    args = ap.parse_args()
    if args.smoke:
        args.quick = True
    grid_json = os.path.join(os.path.dirname(__file__), "results", "grid_knn.json")
    blend_json = os.path.join(os.path.dirname(__file__), "results", "grid_blend.json")
    farfield_json = os.path.join(os.path.dirname(__file__), "results", "farfield.json")
    quadtree_json = os.path.join(os.path.dirname(__file__), "results", "quadtree.json")
    reestimator_json = os.path.join(os.path.dirname(__file__), "results", "reestimator.json")
    tables = {
        "table1": table1_execution_time,
        "fig4": fig4_speedups,
        "fig5": fig5_double_precision,
        "fig6": fig6_layouts,
        "fig7": fig7_tiled_vs_naive,
        "grid": functools.partial(grid_phase1, smoke=args.smoke, json_path=grid_json),
        "plan": functools.partial(grid_plan_reuse, smoke=args.smoke, json_path=grid_json),
        "blend": functools.partial(grid_blend, smoke=args.smoke, json_path=blend_json),
        "farfield": functools.partial(farfield_phase2, smoke=args.smoke, json_path=farfield_json),
        "quadtree": functools.partial(quadtree_phase2, smoke=args.smoke, json_path=quadtree_json),
        "reestimator": functools.partial(reestimator_heal, smoke=args.smoke, json_path=reestimator_json),
        "lm": lm_rooflines,
    }
    only = set(args.only.split(",")) if args.only else None
    print("table,name,value,derived")
    for name, fn in tables.items():
        if only and name not in only:
            continue
        fn(quick=args.quick)


if __name__ == "__main__":
    main()
