import time

import jax


def time_fn(fn, *args, warmup=1, repeats=3, **kw):
    """Median wall-clock seconds of a jitted callable (post-compile)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]
