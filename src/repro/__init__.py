"""repro — production-grade JAX/Pallas reproduction of
"Accelerating Adaptive IDW Interpolation Algorithm on a Single GPU"
(Mei, Xu & Xu, 2015), plus the assigned 10-architecture LM substrate,
multi-pod dry-run and roofline tooling.
"""

__version__ = "0.1.0"
