"""Batched serving launcher: prefill a batch of prompts, then greedy-decode
with the KV-cache serve_step (the path the decode_32k / long_500k dry-run
cells lower).

  PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b --reduced \
      --batch 4 --prompt-len 16 --gen 24
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, smoke
from repro.models import build_model
from repro.models import params as pm
from repro.train import make_prefill_step, make_serve_step, pad_caches


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(smoke(cfg), moe_capacity_factor=4.0)
    model = build_model(cfg)
    key = jax.random.PRNGKey(args.seed)
    params = pm.materialize(model.spec(), key)
    b, t = args.batch, args.prompt_len
    cap = t + args.gen

    batch = {"tokens": jax.random.randint(key, (b, t), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (b, t, cfg.d_model), jnp.float32) * 0.1
    if cfg.family == "vlm":
        batch["visual_embeds"] = jax.random.normal(key, (b, cfg.n_vis_tokens, cfg.d_model)) * 0.1

    prefill = jax.jit(make_prefill_step(model, cfg))
    serve = jax.jit(make_serve_step(model, cfg))

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch)
    caches = pad_caches(caches, cap)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    jax.block_until_ready(tok)
    t_prefill = time.perf_counter() - t0

    out = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        tok, logits, caches = serve(params, caches, tok, jnp.int32(t + i))
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"[serve] arch={cfg.name} batch={b} prompt={t} gen={args.gen}")
    print(f"[serve] prefill {t_prefill*1e3:.1f} ms; decode {t_decode*1e3:.1f} ms "
          f"({(args.gen-1)*b/max(t_decode,1e-9):.1f} tok/s incl. first-call compile)")
    print("[serve] sample tokens:", gen[0, :10].tolist())
    return gen


if __name__ == "__main__":
    main()
