import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run (deliverable e).

For every (architecture x input-shape x mesh) cell:
  jit(step).lower(*abstract_inputs).compile()
on the single-pod 16x16 mesh and the 2x16x16 multi-pod mesh, with the real
production shardings.  Records memory_analysis, cost_analysis and the
collective-byte census parsed from the compiled HLO into
``artifacts/dryrun/<arch>__<shape>__<mesh>.json`` (resumable; failures are
bugs, recorded with tracebacks and a nonzero exit).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--only-missing] [--list]
"""

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCHS, SHAPES, cell_is_applicable, get_arch, get_shape  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import build_cell, cost_analysis_dict  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train.steps import make_prefill_step, make_serve_step, make_train_step  # noqa: E402

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred|c64|c128)\[([\d,]*)\]")


def _shape_bytes(match):
    dt, dims = match.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_census(hlo_text: str):
    """Sum output-shape bytes of every collective op in the compiled HLO.
    (Output bytes are the per-device traffic lower bound; the roofline's
    collective term divides by per-chip link bandwidth.)"""
    census = {c: {"count": 0, "bytes": 0} for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.lstrip()
        # result line looks like: %name = TYPE[shape] opname(...)
        m = re.match(r"%?[\w.\-]+ = (.+?) (" + "|".join(_COLLECTIVES) + r")[\(\.]", s)
        if not m:
            continue
        op = m.group(2)
        ms = _SHAPE_RE.findall(m.group(1))
        total = 0
        for dt, dims in ms:
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            total += n * _DTYPE_BYTES[dt]
        census[op]["count"] += 1
        census[op]["bytes"] += total
    census["total_bytes"] = sum(v["bytes"] for k, v in census.items() if isinstance(v, dict))
    return census


def run_cell(arch_name: str, shape_name: str, mesh_name: str, out_dir: str,
             *, rules_name: str | None = None, accum: int | None = None,
             compress_grads: bool = False, tag: str = ""):
    """One dry-run cell; optional §Perf overrides (alternate rule set,
    accumulation depth, grad compression) write tagged artifacts."""
    import dataclasses

    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    if accum is not None and shape.kind == "train":
        shape = dataclasses.replace(shape, accum_steps=accum)
    ok, why = cell_is_applicable(cfg, shape)
    record = {
        "arch": arch_name,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "status": None,
        "variant": {"rules": rules_name, "accum": accum, "compress_grads": compress_grads} if tag else None,
    }
    suffix = f"__{tag}" if tag else ""
    fname = os.path.join(out_dir, f"{arch_name}__{shape_name}__{mesh_name}{suffix}.json")
    if not ok:
        record.update(status="skipped", reason=why)
        _write(fname, record)
        print(f"[dryrun] SKIP  {arch_name} x {shape_name} x {mesh_name}: {why}")
        return True

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        model = build_model(cfg)
        cell = build_cell(model, cfg, shape, mesh, rules_name=rules_name)
        if cell["kind"] == "train":
            fn = make_train_step(model, cfg, shape, mesh=mesh, rules=cell["rules"],
                                 compress_grads=compress_grads)
        elif cell["kind"] == "prefill":
            fn = make_prefill_step(model, cfg, mesh=mesh, rules=cell["rules"])
        else:
            fn = make_serve_step(model, cfg, mesh=mesh, rules=cell["rules"])

        # donation: train aliases params+opt state; decode aliases the KV/SSM
        # caches (without it the cache update double-buffers — +27 GiB temp on
        # the qwen2-vl decode cell)
        donate = {"train": (0, 1), "prefill": (), "decode": (1,), "long": (1,)}[cell["kind"]]
        jitted = jax.jit(
            fn, in_shardings=cell["in_shardings"], out_shardings=cell["out_shardings"],
            donate_argnums=donate,
        )
        with mesh:
            lowered = jitted.lower(*cell["args"])
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        try:
            mem = compiled.memory_analysis()
            record["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in (
                    "argument_size_in_bytes",
                    "output_size_in_bytes",
                    "temp_size_in_bytes",
                    "generated_code_size_in_bytes",
                )
                if hasattr(mem, k)
            }
            record["memory_analysis_str"] = str(mem)
        except Exception as e:  # pragma: no cover
            record["memory_analysis_error"] = repr(e)

        try:
            ca = cost_analysis_dict(compiled)
            record["cost_analysis"] = {
                k: float(v)
                for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    k in ("flops", "transcendentals", "bytes accessed")
                    or k.startswith("bytes accessed")
                )
            }
        except Exception as e:  # pragma: no cover
            record["cost_analysis_error"] = repr(e)

        hlo = compiled.as_text()
        record["collectives"] = collective_census(hlo)
        record["hlo_bytes"] = len(hlo)
        record["timings_s"] = {"lower": round(t_lower, 2), "compile": round(t_compile, 2)}
        record["devices"] = len(mesh.devices.flatten())
        record["status"] = "ok"
        _write(fname, record)
        print(
            f"[dryrun] OK    {arch_name} x {shape_name} x {mesh_name} "
            f"(lower {t_lower:.1f}s compile {t_compile:.1f}s, "
            f"flops/dev {record.get('cost_analysis', {}).get('flops', float('nan')):.3e}, "
            f"coll {record['collectives']['total_bytes']/1e9:.3f} GB)"
        )
        return True
    except Exception as e:
        record.update(status="failed", error=repr(e), traceback=traceback.format_exc())
        _write(fname, record)
        print(f"[dryrun] FAIL  {arch_name} x {shape_name} x {mesh_name}: {e!r}")
        return False


def run_aidw_cell(work_name: str, mesh_name: str, out_dir: str):
    """Dry-run the AIDW workloads (the paper's own technique) on the
    production meshes — ring-sharded data (collective-permute) or
    replicated-data/sharded-queries."""
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs.aidw import AIDW_WORKLOADS
    from repro.core.distributed import (
        ring_aidw,
        ring_aidw_rotate_queries,
        sharded_queries_aidw,
    )

    w = AIDW_WORKLOADS[work_name]
    record = {"arch": work_name, "shape": w.mode, "mesh": mesh_name, "kind": "aidw", "status": None}
    fname = os.path.join(out_dir, f"{work_name}__{w.mode}__{mesh_name}.json")
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        axes = tuple(mesh.axis_names)
        sds = lambda shape: jax.ShapeDtypeStruct(shape, jnp.float32)
        args = (sds((w.m,)), sds((w.m,)), sds((w.m,)), sds((w.n,)), sds((w.n,)))
        qsh = NamedSharding(mesh, P(axes))
        if w.mode == "ring":
            fn = lambda dx, dy, dz, qx, qy: ring_aidw(
                mesh, dx, dy, dz, qx, qy, params=w.params, area=1.0,
                q_chunk=w.q_chunk, d_chunk=w.d_chunk,
            )
        elif w.mode == "ring_q":
            fn = lambda dx, dy, dz, qx, qy: ring_aidw_rotate_queries(
                mesh, dx, dy, dz, qx, qy, params=w.params, area=1.0,
                q_chunk=w.q_chunk, d_chunk=w.d_chunk,
            )
        else:
            fn = lambda dx, dy, dz, qx, qy: sharded_queries_aidw(
                mesh, dx, dy, dz, qx, qy, params=w.params, area=1.0
            )
        dsh = qsh if w.mode in ("ring", "ring_q") else NamedSharding(mesh, P())
        jitted = jax.jit(fn, in_shardings=(dsh, dsh, dsh, qsh, qsh), out_shardings=(qsh, qsh))
        with mesh:
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        try:
            mem = compiled.memory_analysis()
            record["memory_analysis_str"] = str(mem)
            record["memory_analysis"] = {
                k: int(getattr(mem, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes", "temp_size_in_bytes")
                if hasattr(mem, k)
            }
        except Exception as e:
            record["memory_analysis_error"] = repr(e)
        try:
            ca = cost_analysis_dict(compiled)
            record["cost_analysis"] = {
                k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (k in ("flops", "transcendentals", "bytes accessed") or k.startswith("bytes accessed"))
            }
        except Exception as e:
            record["cost_analysis_error"] = repr(e)
        hlo = compiled.as_text()
        record["collectives"] = collective_census(hlo)
        record["hlo_bytes"] = len(hlo)
        record["timings_s"] = {"lower": round(t_lower, 2), "compile": round(t_compile, 2)}
        record["devices"] = len(mesh.devices.flatten())
        record["workload"] = {"m": w.m, "n": w.n, "k": w.k, "mode": w.mode}
        record["status"] = "ok"
        _write(fname, record)
        print(f"[dryrun] OK    {work_name} x {mesh_name} (lower {t_lower:.1f}s compile {t_compile:.1f}s, "
              f"coll {record['collectives']['total_bytes']/1e9:.3f} GB)")
        return True
    except Exception as e:
        record.update(status="failed", error=repr(e), traceback=traceback.format_exc())
        _write(fname, record)
        print(f"[dryrun] FAIL  {work_name} x {mesh_name}: {e!r}")
        return False


def _write(fname, record):
    os.makedirs(os.path.dirname(fname), exist_ok=True)
    with open(fname, "w") as f:
        json.dump(record, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--aidw", action="store_true", help="run the AIDW workload cells too")
    ap.add_argument("--rules", default=None, help="override rule set (e.g. prefill_cp)")
    ap.add_argument("--accum", type=int, default=None, help="override train accum steps")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--tag", default="", help="artifact suffix for §Perf variants")
    args = ap.parse_args()

    out_dir = args.out or os.path.abspath(ART_DIR)
    if args.arch == "aidw":  # AIDW-only run
        archs = []
        args.aidw = True
    else:
        archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    cells = [(a, s, m) for a in archs for s in shapes for m in meshes]
    if args.list:
        for c in cells:
            print(*c)
        return

    n_fail = 0
    suffix = f"__{args.tag}" if args.tag else ""
    for a, s, m in cells:
        fname = os.path.join(out_dir, f"{a}__{s}__{m}{suffix}.json")
        if args.only_missing and os.path.exists(fname):
            with open(fname) as f:
                if json.load(f).get("status") in ("ok", "skipped"):
                    continue
        if not run_cell(a, s, m, out_dir, rules_name=args.rules, accum=args.accum,
                        compress_grads=args.compress_grads, tag=args.tag):
            n_fail += 1

    if args.aidw or not args.arch:
        from repro.configs.aidw import AIDW_WORKLOADS

        for wname, w in AIDW_WORKLOADS.items():
            for m in meshes:
                fname = os.path.join(out_dir, f"{wname}__{w.mode}__{m}.json")
                if args.only_missing and os.path.exists(fname):
                    with open(fname) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            continue
                if not run_aidw_cell(wname, m, out_dir):
                    n_fail += 1
    print(f"[dryrun] done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
