"""Roofline analysis (deliverable g) over the dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds-per-step on TPU v5e:

  compute    = HLO_FLOPs_per_device / 197e12           (bf16 peak per chip)
  memory     = HLO_bytes_per_device / 819e9            (HBM bandwidth)
  collective = collective_bytes_per_device / 50e9      (one ICI link)

Sources: ``compiled.cost_analysis()`` (flops, bytes accessed — note: XLA's
"bytes accessed" is HLO-level operand traffic, an upper bound on post-fusion
HBM traffic) and the collective census parsed from ``compiled.as_text()``
(output-shape bytes per collective op).  The dominant term is the projected
bottleneck; MODEL_FLOPS/HLO_FLOPs measures how much compiled compute is
"useful" (catches remat and dispatch overhead).

Usage:
  PYTHONPATH=src python -m repro.launch.roofline [--dir artifacts/dryrun]
      [--csv artifacts/roofline.csv] [--md artifacts/roofline.md]
  PYTHONPATH=src python -m repro.launch.roofline --compare A.json B.json
"""

from __future__ import annotations

import argparse
import glob
import json
import os

PEAK_FLOPS = 197e12  # bf16 / chip (TPU v5e)
HBM_BW = 819e9  # B/s / chip
ICI_BW = 50e9  # B/s / link (1 link assumed; see EXPERIMENTS §Roofline)

SUGGEST = {
    "collective": "cut collective bytes (fewer FSDP re-gathers per step, TP->EP resharding, bf16-compressed cross-pod grads)",
    "memory": "raise arithmetic intensity (fusion, flash-style attention blocking, less remat recompute, smaller caches)",
    "compute": "already compute-bound: push MXU utilisation (layouts, larger per-step batch, fewer transcendentals)",
}


def active_params(cfg) -> float:
    """Parameter count with MoE experts scaled to the activated fraction.
    Embedding counted once (standing in for the unembed matmul)."""
    from repro.models import build_model
    from repro.models import params as pm

    model = build_model(cfg)
    spec = model.spec()
    leaves, _ = pm._flatten(spec)
    total = 0.0
    for s in leaves:
        n = 1
        for d in s.shape:
            n *= d
        if "experts" in s.axes and cfg.n_experts:
            n *= cfg.moe_top_k / cfg.n_experts
        total += n
    return total


def model_flops(arch_name: str, shape_name: str) -> float:
    """Analytic useful-FLOPs per step (GLOBAL, all devices)."""
    from repro.configs import get_arch, get_shape

    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    n_act = active_params(cfg)
    b, s = shape.global_batch, shape.seq_len

    def attn_flops(tokens, kv_len, batch):
        if cfg.n_heads == 0:
            return 0.0
        per_layer = 4 * cfg.n_heads * cfg.head_dim * kv_len  # qk^T + a*v per token
        n_attn_layers = sum(
            sum(1 for kind in g.pattern if kind[0] in ("attn", "local", "bidir")) * g.repeats
            for g in cfg.groups
        ) + cfg.n_enc_layers
        return per_layer * n_attn_layers * tokens * batch

    if shape.kind == "train":
        d_tokens = b * s
        return 6 * n_act * d_tokens + 3 * attn_flops(s, s / 2, b)
    if shape.kind == "prefill":
        d_tokens = b * s
        return 2 * n_act * d_tokens + attn_flops(s, s / 2, b)
    # decode / long: one token against a seq_len cache
    return 2 * n_act * b + attn_flops(1, s, b)


def analyze(record: dict, costmodel: dict | None = None) -> dict | None:
    if record.get("status") != "ok":
        return None
    devices = record["devices"]
    ca = record.get("cost_analysis", {})
    flops_dev = ca.get("flops", 0.0)
    bytes_dev = ca.get("bytes accessed", 0.0)
    coll_dev = record.get("collectives", {}).get("total_bytes", 0)
    corrected = False
    if costmodel and costmodel.get("status") == "ok":
        # loop-corrected totals (see launch/costmodel.py: XLA counts while
        # bodies once; scanned stacks must be reconstructed)
        flops_dev = costmodel["corrected"]["flops"]
        bytes_dev = costmodel["corrected"]["bytes_accessed"]
        coll_dev = costmodel["corrected"]["collectives"]["total_bytes"]
        corrected = True
    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    coll_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dominant = max(terms, key=terms.get)
    out = {
        "arch": record["arch"],
        "shape": record["shape"],
        "mesh": record["mesh"],
        "devices": devices,
        "loop_corrected": corrected,
        "flops_per_dev": flops_dev,
        "bytes_per_dev": bytes_dev,
        "coll_bytes_per_dev": coll_dev,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "bound_s": terms[dominant],
        "suggestion": SUGGEST[dominant],
    }
    if record.get("kind") != "aidw":
        try:
            mf = model_flops(record["arch"], record["shape"])
            out["model_flops"] = mf
            out["useful_ratio"] = mf / (flops_dev * devices) if flops_dev else 0.0
            # roofline fraction: useful flops per second at the bound vs peak
            step_s = max(terms.values())
            out["mfu_at_bound"] = mf / devices / step_s / PEAK_FLOPS if step_s else 0.0
        except Exception as e:  # pragma: no cover
            out["model_flops_error"] = repr(e)
    else:
        w = record.get("workload", {})
        if w:
            out.update(_aidw_analytic(record, w, devices))
    return out


# v5e VPU f32 (the AIDW kernels are f32 vector code, not MXU bf16)
PEAK_VPU = PEAK_FLOPS / 4


def _aidw_analytic(record, w, devices):
    """Analytic roofline for the AIDW cells.  The compiled numbers cannot be
    used directly: the ring fori_loop and the chunked fold scans are while
    loops (counted once).  All three terms follow closed forms — the compile
    itself is the schedulability proof.

    flops/pair: 7 distance + 3k merge (amortised) + 7 distance + 8 weight.
    """
    m, n, k = w["m"], w["n"], w["k"]
    mode = w.get("mode", "ring")
    pairs_dev = (n / devices) * m
    flops_dev = (7 + 3 * k + 7 + 8) * pairs_dev
    # HBM: each data point re-read once per resident query chunk, two sweeps
    q_chunk = 1024
    hbm_dev = (n / devices / q_chunk) * m * (8 + 12)
    if mode == "ring":
        # nshards rotations x (m/nshards) points x (x,y | x,y,z) f32
        coll_dev = m * (8 + 12)
    elif mode == "ring_q":
        # nshards rotations x (n/nshards) queries x (q+best | q+partials) f32
        coll_dev = n * ((2 + k) * 4 + 7 * 4)
    else:
        coll_dev = 0.0
    compute_s = flops_dev / PEAK_VPU
    memory_s = hbm_dev / HBM_BW
    coll_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": coll_s}
    dom = max(terms, key=terms.get)
    mf = 29.0 * m * n  # useful pair work (both sweeps + weights, excl. merge)
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dom,
        "bound_s": terms[dom],
        "model_flops": mf,
        "useful_ratio": mf / (flops_dev * devices),
        "mfu_at_bound": mf / devices / terms[dom] / PEAK_VPU if terms[dom] else 0.0,
        "analytic": True,
        "suggestion": SUGGEST[dom],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "dryrun"))
    ap.add_argument("--csv", default=None)
    ap.add_argument("--md", default=None)
    ap.add_argument("--mesh", default="single", help="mesh for the main table (single|multi|both)")
    ap.add_argument("--compare", nargs=2, default=None, metavar=("BASE", "NEW"))
    args = ap.parse_args()

    def load_cell(path):
        rec = json.load(open(path))
        cm_path = os.path.join(os.path.dirname(path), "..", "costmodel", os.path.basename(path))
        cm = json.load(open(cm_path)) if os.path.exists(cm_path) else None
        return analyze(rec, cm)

    if args.compare:
        base = load_cell(args.compare[0])
        new = load_cell(args.compare[1])
        for k in ("compute_s", "memory_s", "collective_s", "bound_s"):
            b, n = base[k], new[k]
            d = (n - b) / b * 100 if b else float("nan")
            print(f"{k:14s} {b:10.4f} -> {n:10.4f}  ({d:+.1f}%)")
        print(f"dominant: {base['dominant']} -> {new['dominant']}")
        return

    rows = []
    for f in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        r = load_cell(f)
        if r and (args.mesh == "both" or r["mesh"] == args.mesh):
            rows.append(r)
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))

    hdr = f"{'arch':22s} {'shape':12s} {'mesh':6s} {'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} {'dominant':>10s} {'useful':>7s} {'MFU@bound':>9s}"
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        print(
            f"{r['arch']:22s} {r['shape']:12s} {r['mesh']:6s} "
            f"{r['compute_s']:10.4f} {r['memory_s']:10.4f} {r['collective_s']:10.4f} "
            f"{r['dominant']:>10s} {r.get('useful_ratio', float('nan')):7.2f} "
            f"{r.get('mfu_at_bound', float('nan')):9.3f}"
        )

    if args.csv:
        import csv

        keys = ["arch", "shape", "mesh", "devices", "flops_per_dev", "bytes_per_dev",
                "coll_bytes_per_dev", "compute_s", "memory_s", "collective_s",
                "dominant", "model_flops", "useful_ratio", "mfu_at_bound", "suggestion"]
        with open(args.csv, "w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=keys, extrasaction="ignore")
            w.writeheader()
            w.writerows(rows)
        print(f"wrote {args.csv}")

    if args.md:
        with open(args.md, "w") as f:
            f.write("| arch | shape | mesh | compute (s) | memory (s) | collective (s) | dominant | useful ratio | MFU@bound |\n")
            f.write("|---|---|---|---|---|---|---|---|---|\n")
            for r in rows:
                f.write(
                    f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['compute_s']:.4f} "
                    f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} | **{r['dominant']}** "
                    f"| {r.get('useful_ratio', float('nan')):.2f} | {r.get('mfu_at_bound', float('nan')):.3f} |\n"
                )
        print(f"wrote {args.md}")


if __name__ == "__main__":
    main()
