"""Abstract input specs (ShapeDtypeStruct) + shardings for every
(arch x shape) cell — the dry-run stand-ins.  No device allocation happens
here: params, optimizer state, batches and KV caches are all abstract.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import ArchConfig, ShapeConfig
from repro.models import params as pm
from repro.sharding.rules import RULE_SETS, sharding_for

TRAIN_PARAM_DTYPE = jnp.float32
SERVE_PARAM_DTYPE = jnp.bfloat16

BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "frames": ("batch", "seq", "act_embed"),
    "visual_embeds": ("batch", None, "act_embed"),
    "mrope_positions": (None, "batch", "seq"),
}


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, *, with_labels: bool):
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": _sds((b, s), jnp.int32)}
    if with_labels:
        out["labels"] = _sds((b, s), jnp.int32)
    if cfg.family == "audio":
        out["frames"] = _sds((b, s, cfg.d_model), jnp.bfloat16)
    if cfg.family == "vlm":
        out["visual_embeds"] = _sds((b, cfg.n_vis_tokens, cfg.d_model), jnp.bfloat16)
        out["mrope_positions"] = _sds((3, b, s), jnp.int32)
    return out


def batch_shardings(batch, rules, mesh):
    return {
        k: sharding_for(BATCH_AXES[k], v.shape, rules, mesh) for k, v in batch.items()
    }


def cache_abstract(model, cfg, batch: int, seq: int):
    """(abstract_tree, axes_tree) from the model's (shape, axes, dtype) cache spec."""
    leaves_spec = model.cache_spec(batch, seq)
    is_leaf = lambda x: isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple)
    abstract = jax.tree.map(lambda l: _sds(l[0], l[2]), leaves_spec, is_leaf=is_leaf)
    axes = jax.tree.map(lambda l: l[1], leaves_spec, is_leaf=is_leaf)
    return abstract, axes


def tree_shardings(axes_tree, abstract_tree, rules, mesh):
    # logical-axis leaves are tuples -> flatten relative to the array tree
    leaves, treedef = jax.tree.flatten(abstract_tree)
    axes_leaves = treedef.flatten_up_to(axes_tree)
    return treedef.unflatten(
        [sharding_for(ax, a.shape, rules, mesh) for ax, a in zip(axes_leaves, leaves)]
    )


def build_cell(model, cfg: ArchConfig, shape: ShapeConfig, mesh, rules_name: str | None = None):
    """Everything the dry-run needs for one cell:
    returns dict(kind, args=(abstract...), in_shardings, out_shardings, rules).
    rules_name overrides the default RULE_SETS[shape.kind] (§Perf variants)."""
    rules = RULE_SETS[rules_name or shape.kind]
    spec = model.spec()
    paxes = pm.axes_tree(spec)
    repl = NamedSharding(mesh, jax.sharding.PartitionSpec())

    if shape.kind == "train":
        params = pm.abstract(spec, TRAIN_PARAM_DTYPE)
        psh = tree_shardings(paxes, params, rules, mesh)
        opt = {
            "m": params,
            "v": params,
            "step": _sds((), jnp.int32),
        }
        osh = {"m": psh, "v": psh, "step": repl}
        batch = batch_specs(cfg, shape, with_labels=True)
        bsh = batch_shardings(batch, rules, mesh)
        step_sds = _sds((), jnp.int32)
        metrics_sh = {"loss": repl, "grad_norm": repl, "lr": repl}
        return dict(
            kind="train",
            rules=rules,
            args=(params, opt, batch, step_sds),
            in_shardings=(psh, osh, bsh, repl),
            out_shardings=(psh, osh, metrics_sh),
        )

    params = pm.abstract(spec, SERVE_PARAM_DTYPE)
    psh = tree_shardings(paxes, params, rules, mesh)

    if shape.kind == "prefill":
        batch = batch_specs(cfg, shape, with_labels=False)
        bsh = batch_shardings(batch, rules, mesh)
        cabs, caxes = cache_abstract(model, cfg, shape.global_batch, shape.seq_len)
        csh = tree_shardings(caxes, cabs, rules, mesh)
        logits_sh = sharding_for(("batch", "vocab"), (shape.global_batch, cfg.vocab_size), rules, mesh)
        return dict(
            kind="prefill",
            rules=rules,
            args=(params, batch),
            in_shardings=(psh, bsh),
            out_shardings=(logits_sh, csh),
        )

    # decode / long -> serve_step(params, caches, tokens, pos)
    cabs, caxes = cache_abstract(model, cfg, shape.global_batch, shape.seq_len)
    csh = tree_shardings(caxes, cabs, rules, mesh)
    tokens = _sds((shape.global_batch, 1), jnp.int32)
    tsh = sharding_for(("batch", None), tokens.shape, rules, mesh)
    pos = _sds((), jnp.int32)
    logits_sh = sharding_for(("batch", "vocab"), (shape.global_batch, cfg.vocab_size), rules, mesh)
    return dict(
        kind=shape.kind,
        rules=rules,
        args=(params, cabs, tokens, pos),
        in_shardings=(psh, csh, tsh, repl),
        out_shardings=(tsh, logits_sh, csh),
    )


def cost_analysis_dict(compiled) -> dict:
    """Version-portable ``compiled.cost_analysis()``: older jax returns a
    single dict, the 0.4.3x era returns a one-element list of dicts (one per
    executable).  Every caller goes through this so the shape difference is
    absorbed in one place (same policy as ``kernels/_common.py``'s
    compiler-params shim)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca
