"""Production training launcher.

On real hardware this runs under the production mesh with the full configs;
on this CPU box, ``--reduced`` trains the same code paths end-to-end at smoke
scale (this is examples/train_lm.py's engine).

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --reduced \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance: periodic atomic checkpoints, --resume restarts from the
latest one (mesh-elastic: the checkpoint re-shards onto whatever mesh the
restart uses), straggler watchdog events are logged.
"""

from __future__ import annotations

import argparse
import dataclasses
import os

import jax

from repro.checkpoint import Checkpointer
from repro.configs import SHAPES, ShapeConfig, get_arch, smoke
from repro.data.synthetic import batch_for_arch
from repro.models import build_model
from repro.models import params as pm
from repro.optim import AdamWConfig, adamw_init
from repro.train import LoopConfig, make_train_step, train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--reduced", action="store_true", help="smoke-size config/batch (CPU)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--seq", type=int, default=None)
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(smoke(cfg), moe_capacity_factor=2.0)
    shape = SHAPES[args.shape]
    b = args.batch or (4 if args.reduced else shape.global_batch)
    s = args.seq or (64 if args.reduced else shape.seq_len)
    accum = args.accum or (2 if args.reduced else shape.accum_steps)
    shape = ShapeConfig(shape.name, "train", s, b, accum_steps=accum)

    model = build_model(cfg)
    spec = model.spec()
    print(f"[train] arch={cfg.name} params={pm.count_params(spec)/1e6:.2f}M batch={b} seq={s} accum={accum}")
    params = pm.materialize(spec, jax.random.PRNGKey(args.seed))
    opt_state = adamw_init(params)

    step_fn = jax.jit(
        make_train_step(
            model, cfg, shape, opt=AdamWConfig(lr=args.lr), remat=not args.reduced,
            compress_grads=args.compress_grads,
        )
    )
    ckpt = Checkpointer(args.ckpt_dir or os.path.join("/tmp", f"ckpt_{cfg.name}"), keep=3)
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        state, restored = ckpt.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = restored + 1
        print(f"[train] resumed from step {restored}")

    def batch_fn(step):
        return batch_for_arch(cfg, shape, step, seed=args.seed)

    params, opt_state, events = train_loop(
        step_fn, params, opt_state, batch_fn, ckpt,
        LoopConfig(num_steps=args.steps, ckpt_every=args.ckpt_every, log_every=10),
        start_step=start,
    )
    print(f"[train] done: restarts={events.restarts} stragglers={events.stragglers} "
          f"ckpts={events.saved_steps}")
    return params


if __name__ == "__main__":
    main()
