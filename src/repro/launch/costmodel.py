import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Loop-corrected cost model for the roofline (companion to dryrun.py).

XLA's HloCostAnalysis visits a while-loop body ONCE — scanned layer stacks
and the grad-accumulation loop are under-counted by their trip counts
(verified: scan(10 matmuls) reports the flops of one).  The production
artifact keeps its scans (that's the deployable program and the
memory_analysis source); THIS pass reconstructs exact per-step totals from
small **unrolled** compiles, exploiting that cost is exactly linear in group
repeats:

  variants:  base     — every GroupDef.repeats=1 (and 1 encoder layer)
             group_i  — group i at repeats=2 (marginal = one extra group body)
  F_micro  = F(base) + sum_i (G_i - 1) * (F(group_i) - F(base))
  F_step   = accum_steps * F_micro          (train; optimizer flops, ~1e-5 of
                                             a step, ride along per microbatch)
           = F_micro                        (prefill / decode)

The same linearity corrects "bytes accessed" and the collective census.
Known residual: the Mamba2 inter-chunk state scan stays a while loop inside
the body (its per-chunk state update is O(B*H*P*N), ~1e-4 of the chunk's
GEMMs — negligible and noted in EXPERIMENTS §Roofline).

Writes artifacts/costmodel/<arch>__<shape>__<mesh>.json.
"""

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ARCHS, SHAPES, cell_is_applicable, get_arch, get_shape  # noqa: E402
from repro.launch.dryrun import collective_census, _write  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import build_cell, cost_analysis_dict  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train.steps import make_prefill_step, make_serve_step, make_train_step  # noqa: E402

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "artifacts", "costmodel")


def _reduced_cfg(cfg, repeats_map, n_enc):
    groups = tuple(
        dataclasses.replace(g, repeats=repeats_map[i]) for i, g in enumerate(cfg.groups)
    )
    return dataclasses.replace(cfg, groups=groups, n_enc_layers=n_enc)


def _measure(cfg, shape, mesh, rules_name=None, compress_grads=False):
    """Compile one unrolled variant; return (flops, bytes, collective census)."""
    model = build_model(cfg)
    cell = build_cell(model, cfg, shape, mesh, rules_name=rules_name)
    if cell["kind"] == "train":
        fn = make_train_step(model, cfg, shape, mesh=mesh, rules=cell["rules"], unroll=True,
                             compress_grads=compress_grads)
    elif cell["kind"] == "prefill":
        fn = make_prefill_step(model, cfg, mesh=mesh, rules=cell["rules"], unroll=True)
    else:
        fn = make_serve_step(model, cfg, mesh=mesh, rules=cell["rules"], unroll=True)
    jitted = jax.jit(fn, in_shardings=cell["in_shardings"], out_shardings=cell["out_shardings"])
    with mesh:
        compiled = jitted.lower(*cell["args"]).compile()
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    census = collective_census(compiled.as_text())
    return flops, byts, census


def run_cell(arch_name, shape_name, mesh_name, out_dir, *, rules_name=None,
             accum_override=None, compress_grads=False, tag=""):
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    if accum_override is not None and shape.kind == "train":
        shape = dataclasses.replace(shape, accum_steps=accum_override)
    ok, why = cell_is_applicable(cfg, shape)
    suffix = f"__{tag}" if tag else ""
    fname = os.path.join(out_dir, f"{arch_name}__{shape_name}__{mesh_name}{suffix}.json")
    record = {"arch": arch_name, "shape": shape_name, "mesh": mesh_name, "status": None,
              "variant": {"rules": rules_name, "accum": accum_override,
                          "compress_grads": compress_grads} if tag else None}
    if not ok:
        record.update(status="skipped", reason=why)
        _write(fname, record)
        return True
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        accum = max(shape.accum_steps, 1) if shape.kind == "train" else 1
        cost_shape = (
            dataclasses.replace(shape, global_batch=shape.global_batch // accum, accum_steps=1)
            if shape.kind == "train"
            else shape
        )
        ones = {i: 1 for i in range(len(cfg.groups))}
        enc1 = 1 if cfg.n_enc_layers else 0
        base_cfg = _reduced_cfg(cfg, ones, enc1)
        f0, b0, c0 = _measure(base_cfg, cost_shape, mesh, rules_name, compress_grads)
        flops, byts = f0, b0
        census = {k: dict(v) if isinstance(v, dict) else v for k, v in c0.items()}
        marginals = {}
        for i, g in enumerate(cfg.groups):
            if g.repeats <= 1:
                continue
            var_cfg = _reduced_cfg(cfg, {**ones, i: 2}, enc1)
            fi, bi, ci = _measure(var_cfg, cost_shape, mesh, rules_name, compress_grads)
            mult = g.repeats - 1
            flops += mult * (fi - f0)
            byts += mult * (bi - b0)
            for op in census:
                if isinstance(census[op], dict):
                    census[op]["bytes"] += mult * (ci[op]["bytes"] - c0[op]["bytes"])
                    census[op]["count"] += mult * (ci[op]["count"] - c0[op]["count"])
            marginals[f"g{i}"] = {"flops": fi - f0, "bytes": bi - b0, "repeats": g.repeats}
        if cfg.n_enc_layers > 1:
            var_cfg = _reduced_cfg(cfg, ones, 2)
            fe, be, ce = _measure(var_cfg, cost_shape, mesh, rules_name, compress_grads)
            mult = cfg.n_enc_layers - 1
            flops += mult * (fe - f0)
            byts += mult * (be - b0)
            for op in census:
                if isinstance(census[op], dict):
                    census[op]["bytes"] += mult * (ce[op]["bytes"] - c0[op]["bytes"])
                    census[op]["count"] += mult * (ce[op]["count"] - c0[op]["count"])
            marginals["enc"] = {"flops": fe - f0, "bytes": be - b0, "repeats": cfg.n_enc_layers}

        flops *= accum
        byts *= accum
        for op in census:
            if isinstance(census[op], dict):
                census[op]["bytes"] *= accum
                census[op]["count"] *= accum
        census["total_bytes"] = sum(
            v["bytes"] for v in census.values() if isinstance(v, dict)
        )
        record.update(
            status="ok",
            devices=len(mesh.devices.flatten()),
            accum=accum,
            corrected={"flops": flops, "bytes_accessed": byts, "collectives": census},
            base={"flops": f0, "bytes_accessed": b0},
            marginals=marginals,
            timings_s=round(time.time() - t0, 1),
        )
        _write(fname, record)
        print(
            f"[costmodel] OK   {arch_name} x {shape_name} x {mesh_name} "
            f"flops/dev {flops:.3e} coll {census['total_bytes']/1e9:.2f} GB ({record['timings_s']}s)"
        )
        return True
    except Exception as e:
        record.update(status="failed", error=repr(e), traceback=traceback.format_exc())
        _write(fname, record)
        print(f"[costmodel] FAIL {arch_name} x {shape_name} x {mesh_name}: {e!r}")
        return False


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--rules", default=None)
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()
    out_dir = args.out or os.path.abspath(ART_DIR)
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    n_fail = 0
    for a in archs:
        for s in shapes:
            for m in meshes:
                suffix = f"__{args.tag}" if args.tag else ""
                fname = os.path.join(out_dir, f"{a}__{s}__{m}{suffix}.json")
                if args.only_missing and os.path.exists(fname):
                    with open(fname) as f:
                        if json.load(f).get("status") in ("ok", "skipped"):
                            continue
                if not run_cell(a, s, m, out_dir, rules_name=args.rules,
                                accum_override=args.accum,
                                compress_grads=args.compress_grads, tag=args.tag):
                    n_fail += 1
    print(f"[costmodel] done; {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
