"""Production meshes.

Function (not module constant) so importing never touches jax device state —
the dry-run sets XLA_FLAGS before its first jax call and only then builds the
mesh.
"""

from __future__ import annotations

import math

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds the cross-DCN "pod" axis
    (2 pods = 512 chips).  Uses the first prod(shape) devices so the
    single-pod mesh also builds under the 512-device dry-run flag."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = math.prod(shape)
    devs = jax.devices()[:need]
    if len(devs) < need:
        raise RuntimeError(
            f"mesh {shape} needs {need} devices, have {len(jax.devices())} "
            "(the dry-run sets --xla_force_host_platform_device_count=512)"
        )
    return jax.make_mesh(shape, axes, devices=devs)


def make_debug_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for simulated-device tests."""
    need = math.prod(shape)
    return jax.make_mesh(shape, axes, devices=jax.devices()[:need])
