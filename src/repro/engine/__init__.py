"""Plan/execute engine — the serving-shaped front door for every AIDW/IDW
implementation (DESIGN.md §6).

``build_plan`` runs ONCE per dataset, eagerly, and captures everything
shape- and occupancy-dependent (padded data layouts, the grid's CSR
snapshot, the static candidate capacity, autotuned block sizes).
``execute(plan, qx, qy)`` is a pure, jit-compatible function for *all*
impls — including ``grid``, which was eager-only before this engine — so a
plan is built once and reused across query batches with zero retraces:

    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    z1, a1 = execute(plan, qx1, qy1)     # compiles
    z2, a2 = execute(plan, qx2, qy2)     # cache hit (same shapes)
"""

from repro.engine.plan import InterpolationPlan, build_plan, replan_with_capacity
from repro.engine.execute import execute, execute_with_stats

__all__ = ["InterpolationPlan", "build_plan", "execute", "execute_with_stats",
           "replan_with_capacity"]
