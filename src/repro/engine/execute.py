"""Execute — the traced half of the plan/execute engine.

``execute(plan, qx, qy)`` is a pure function of its arguments for every
impl.  All shapes inside are fixed by (plan statics, query count), so the
jitted entry points compile once per (plan configuration, query shape) and
hit the cache on every further batch — the "build once, execute per
request" serving shape.

The grid path (DESIGN.md §6) runs entirely under the trace: Morton sort,
seam-split block layout, per-query safe radii from the plan's
``required_radius`` table (closed form — no while-loop), the
static-capacity CSR candidate gather, the sparsity-skipping Phase 1 over
candidate rows and the full-data Phase 2 — or, for
``build_plan(phase2="farfield")`` plans, the near/far split Phase 2 with a
plan-proved error bound (DESIGN.md §7), or, for ``phase2="quadtree"``
plans, the multi-level Barnes–Hut far field whose per-node opening
criterion and dipole correction make the bound second-order (DESIGN.md
§8).  Exactness is unconditional and
now *per block*: the kernel result is kept wherever a block's candidates
fit the plan's capacity, and queries in overflowing blocks (far out-of-bbox
queries, query distributions unlike the data) get their alpha from the
exact expanding-ring search run *only for them* (masked) — the worst case
is O(overflowed queries), never the whole batch.
"""

from __future__ import annotations

import threading
import warnings
import weakref

import jax
import jax.numpy as jnp

from repro.core.aidw import _interpolate_pass2, adaptive_alpha, brute_r_obs
from repro.core.grid import (
    cell_of,
    grid_r_obs,
    morton_ids,
    safe_radius_from_need,
    seam_layout,
    seam_segment_ids,
)
from repro.core.layouts import pad_tail, pad_to
from repro.engine.plan import InterpolationPlan
from repro.errors import CapacityOverflowWarning
from repro.kernels.aidw_fused import aidw_fused_soa
from repro.kernels.aidw_grid import (
    block_rectangles,
    gather_candidates_csr,
    phase1_alpha_from_candidates,
    phase2_far_aggregates,
    phase2_far_nodes,
    phase2_near_weights,
    phase2_weights_full,
)
from repro.kernels.aidw_naive import aidw_naive_aoas, aidw_naive_soa
from repro.kernels.aidw_tiled import aidw_tiled_aoas, aidw_tiled_soa
from repro.kernels.aidw_tiled_v2 import aidw_tiled_v2_soa
from repro.kernels.idw_tiled import idw_tiled_soa


def _seam_split_layout(plan: InterpolationPlan, qx_s, qy_s, cx_s, cy_s):
    """Regroup the Morton-sorted batch so no block straddles a Morton seam.

    The plan's ``seam_level`` is capped per batch so the worst-case block
    padding (one block per occupied quadrant) stays small relative to the
    batch; everything is static given the query shape.  Returns the blocked
    view ``(qx_v, qy_v, cx_v, cy_v)`` plus ``src`` (slot -> sorted index:
    maps per-query arrays like the blended alpha INTO the view) and ``dest``
    (sorted index -> slot: maps per-slot results back); both ``None`` when
    splitting is off — the view IS the sorted layout.  The exact Phase 2
    never sees the split layout (alpha is gathered back through ``dest``,
    so its full-data sweep cost is untouched); the far-field Phase 2 runs
    in the view, whose per-block rectangles it shares with Phase 1.
    """
    n_tot = qx_s.shape[0]
    level = plan.seam_level
    while level > 0 and (4 ** level) * plan.block_q > n_tot:
        level -= 1
    if level == 0:
        return qx_s, qy_s, cx_s, cy_s, None, None
    seg = seam_segment_ids(plan.grid, cx_s, cy_s, level)
    n_slots = n_tot + (4 ** level) * plan.block_q
    src, dest = seam_layout(seg, 4 ** level, plan.block_q, n_slots)
    return qx_s[src], qy_s[src], cx_s[src], cy_s[src], src, dest


def _tile_table(need, capacity: int, block_d: int, pipeline: str):
    """Per-block real-tile counts for the scalar-prefetch pipelines — the ONE
    place the "dense walk is bit-identical because skipped tiles are
    all-sentinel" invariant is encoded.  "prefetch" clamps each block to the
    tiles its (capacity-covered) candidates occupy; "dense" walks every
    static tile."""
    if pipeline == "prefetch":
        covered = jnp.minimum(need, capacity)
        return (covered + block_d - 1) // block_d
    return jnp.full(need.shape, capacity // block_d, jnp.int32)


def _phase2_farfield(plan: InterpolationPlan, qx_v, qy_v, alpha_v,
                     cx_v=None, cy_v=None):
    """Far-field Phase 2 over a blocked query view (DESIGN.md §7).

    ``qx_v/qy_v`` is any Morton-blocked layout whose length is a multiple of
    ``plan.block_q`` (the engine passes the seam-split Phase-1 view, the
    benchmark the plain sorted batch); ``alpha_v (n_tot, 1)`` the matching
    per-slot alpha; ``cx_v/cy_v`` the view's clamped home cells if the
    caller already holds them.  Per block: the near rectangle is the
    home-cell bbox expanded by the plan's near-field radius; its points are
    swept exactly (CSR gather at the static ``p2_capacity``, tile-table
    skip), every cell outside it contributes one aggregate term.  Returns
    ``(z (n_tot, 1), need (nb,), rect_cells (nb,))`` — ``need >
    p2_capacity`` flags blocks whose near gather was truncated; the caller
    must route those queries to the exact sweep (the error bound assumes a
    complete near field).
    """
    grid = plan.grid
    if cx_v is None or cy_v is None:
        cx_v, cy_v = cell_of(grid, qx_v, qy_v)
    r_near = jnp.full(cx_v.shape, plan.farfield_radius, jnp.int32)
    xlo, xhi, ylo, yhi = block_rectangles(grid, cx_v, cy_v, r_near, plan.block_q)
    cand_x, cand_y, cand_z, need = gather_candidates_csr(
        grid, xlo, xhi, ylo, yhi, plan.p2_capacity, with_z=True
    )
    num_tiles = _tile_table(need, plan.p2_capacity, plan.p2_block_d,
                            plan.pipeline)
    ah = alpha_v * 0.5
    sw_n, swz_n, md_n, hz_n = phase2_near_weights(
        qx_v, qy_v, ah, cand_x, cand_y, cand_z, num_tiles,
        block_q=plan.block_q, block_d=plan.p2_block_d, interpret=plan.interpret,
    )
    rects = jnp.stack([xlo, xhi, ylo, yhi], axis=1)
    sw_f, swz_f = phase2_far_aggregates(
        qx_v, qy_v, ah, rects, plan.far,
        block_q=plan.block_q, block_d=plan.p2_far_block_d,
        interpret=plan.interpret,
    )
    z = jnp.where(md_n <= plan.params.exact_hit_eps, hz_n,
                  (swz_n + swz_f) / (sw_n + sw_f))
    rect_cells = (xhi - xlo + 1) * (yhi - ylo + 1)
    return z, need, rect_cells


def _quadtree_walk(plan: InterpolationPlan, hxlo, hxhi, hylo, hyhi):
    """Barnes–Hut walk over the plan's quadtree, one table per level.

    Per query block (home rectangle ``hxlo..hxhi x hylo..hyhi``, inclusive
    cell coords) and per level, every node gets the OPENING criterion: a
    node is CLOSED — emitted as one aggregate+dipole term — iff its
    Chebyshev cell gap from the home rectangle clears ``radius + 1`` (its
    cells are all outside the near rectangle, and the ring invariant gives
    every point distance ``>= (gap-1) * cell_min``) and its stored
    dispersion fits the plan's opening ratio, ``e <= tau * (gap-1) *
    cell_min`` — so each term's own tau never exceeds ``plan.qt_tau`` and
    the plan's dipole bound covers it.  A processed node failing the
    criterion is OPENED: its four children are processed at the next finer
    level.  Level-0 cells cannot be opened further and are force-closed on
    the gap test alone (``tau_eff`` was chosen at plan time to cover them).
    Empty nodes are neither opened nor emitted.  Induction over levels
    gives the partition the error budget needs: every far cell is counted
    by EXACTLY one closed node, every near cell by none.

    The walk is plain masked arithmetic over all ``(block, node)`` pairs —
    cheap bools, no weights — while the expensive weight evaluation runs
    only over the ~O(log m) closed nodes each block compacts into its
    static ``(nb, k_pad)`` id tables (pad slots point at the sentinel
    node).  Returns per level ``(table, n_closed, n_opened, n_processed)``;
    ``n_closed > k_pad`` means the table overflowed and the caller must
    route the block to the exact sweep.
    """
    grid = plan.grid
    dtype = grid.pt_x.dtype
    radius = plan.farfield_radius
    tau = plan.qt_tau
    cell_min = jnp.minimum(grid.cell_size[0], grid.cell_size[1]).astype(dtype)
    nb = hxlo.shape[0]
    n_lv = len(plan.qt_levels)
    out = [None] * n_lv
    opened_up = None
    parent_nx = 0
    for lv in range(n_lv - 1, -1, -1):
        nx, ny, step, k_pad, _tile = plan.qt_levels[lv]
        n_nodes = nx * ny
        jx = jnp.arange(nx, dtype=jnp.int32)
        jy = jnp.arange(ny, dtype=jnp.int32)
        nxlo = jx * step
        nxhi = jnp.minimum((jx + 1) * step, grid.gx) - 1
        nylo = jy * step
        nyhi = jnp.minimum((jy + 1) * step, grid.gy) - 1
        gapx = jnp.maximum(jnp.maximum(nxlo[None, :] - hxhi[:, None],
                                       hxlo[:, None] - nxhi[None, :]), 0)
        gapy = jnp.maximum(jnp.maximum(nylo[None, :] - hyhi[:, None],
                                       hylo[:, None] - nyhi[None, :]), 0)
        gap = jnp.maximum(gapy[:, :, None], gapx[:, None, :]).reshape(nb, n_nodes)
        cnt = plan.far[lv][2][:n_nodes]
        e = plan.far[lv][6][:n_nodes]
        if lv == n_lv - 1:
            proc = jnp.ones((nb, n_nodes), bool)
        else:
            pids = ((jy[:, None] // 2) * parent_nx + (jx[None, :] // 2)).reshape(-1)
            proc = opened_up[:, pids]
        parent_nx = nx
        nonempty = (cnt > 0)[None, :]
        far_enough = gap >= radius + 1
        if lv == 0:
            closed = proc & far_enough & nonempty
            n_opened = jnp.zeros((nb,), jnp.int32)
        else:
            tight = e[None, :] <= tau * (gap - 1).astype(dtype) * cell_min
            closed = proc & far_enough & tight & nonempty
            opened = proc & nonempty & ~(far_enough & tight)
            opened_up = opened
            n_opened = jnp.sum(opened.astype(jnp.int32), axis=1)
        n_proc = jnp.sum((proc & nonempty).astype(jnp.int32), axis=1)
        n_closed = jnp.sum(closed.astype(jnp.int32), axis=1)
        # compact the closed ids into the static-width table: cumsum
        # positions, one dump slot past k_pad for everything else
        pos = jnp.cumsum(closed.astype(jnp.int32), axis=1) - 1
        col = jnp.where(closed, jnp.minimum(pos, k_pad), k_pad)
        ids = jnp.broadcast_to(jnp.arange(n_nodes, dtype=jnp.int32)[None, :],
                               (nb, n_nodes))
        tbl = jnp.full((nb, k_pad + 1), n_nodes, jnp.int32)
        tbl = tbl.at[jnp.arange(nb, dtype=jnp.int32)[:, None], col].set(
            jnp.where(closed, ids, n_nodes), mode="drop"
        )
        out[lv] = (tbl[:, :k_pad], n_closed, n_opened, n_proc)
    return out


def _phase2_quadtree(plan: InterpolationPlan, qx_v, qy_v, alpha_v,
                     cx_v=None, cy_v=None):
    """Quadtree far-field Phase 2 over a blocked query view (DESIGN.md §8).

    The near field is the single-level arm's, verbatim: exact per-point
    weights over the home rectangle expanded by ``plan.farfield_radius``
    (CSR gather at ``p2_capacity``, tile-table skip).  The far field runs
    :func:`_quadtree_walk` and then one :func:`phase2_far_nodes` sweep per
    level over the gathered node tables, accumulating into the same
    ``(sum_w, sum_wz)`` the near sweep produced.  Returns ``(z, need,
    overflow, rect_cells, closed_counts, opened_tot, proc_tot)`` —
    ``overflow (nb,)`` flags blocks whose near gather OR any level table
    was truncated (their queries must take the exact sweep; the bound
    assumes completeness), ``closed_counts`` the per-level ``(nb,)`` closed
    node counts for the stats dict.
    """
    grid = plan.grid
    if cx_v is None or cy_v is None:
        cx_v, cy_v = cell_of(grid, qx_v, qy_v)
    r_zero = jnp.zeros(cx_v.shape, jnp.int32)
    hxlo, hxhi, hylo, hyhi = block_rectangles(grid, cx_v, cy_v, r_zero,
                                              plan.block_q)
    r_near = jnp.full(cx_v.shape, plan.farfield_radius, jnp.int32)
    xlo, xhi, ylo, yhi = block_rectangles(grid, cx_v, cy_v, r_near, plan.block_q)
    cand_x, cand_y, cand_z, need = gather_candidates_csr(
        grid, xlo, xhi, ylo, yhi, plan.p2_capacity, with_z=True
    )
    num_tiles = _tile_table(need, plan.p2_capacity, plan.p2_block_d,
                            plan.pipeline)
    ah = alpha_v * 0.5
    sw, swz, md_n, hz_n = phase2_near_weights(
        qx_v, qy_v, ah, cand_x, cand_y, cand_z, num_tiles,
        block_q=plan.block_q, block_d=plan.p2_block_d, interpret=plan.interpret,
    )
    overflow = need > plan.p2_capacity
    closed_counts = []
    opened_tot = jnp.zeros(need.shape, jnp.int32)
    proc_tot = jnp.zeros(need.shape, jnp.int32)
    tables = _quadtree_walk(plan, hxlo, hxhi, hylo, hyhi)
    for lv, (tbl, n_closed, n_opened, n_proc) in enumerate(tables):
        _nx, _ny, _step, k_pad, tile = plan.qt_levels[lv]
        fx, fy, fcnt, fzs, fmx, fmy, _fe = plan.far[lv]
        covered = jnp.minimum(n_closed, k_pad)
        nt = (covered + tile - 1) // tile
        sw_f, swz_f = phase2_far_nodes(
            qx_v, qy_v, ah, fx[tbl], fy[tbl], fcnt[tbl], fzs[tbl],
            fmx[tbl], fmy[tbl], nt,
            block_q=plan.block_q, block_d=tile, interpret=plan.interpret,
        )
        sw = sw + sw_f
        swz = swz + swz_f
        overflow = overflow | (n_closed > k_pad)
        closed_counts.append(n_closed)
        opened_tot = opened_tot + n_opened
        proc_tot = proc_tot + n_proc
    z = jnp.where(md_n <= plan.params.exact_hit_eps, hz_n, swz / sw)
    rect_cells = (xhi - xlo + 1) * (yhi - ylo + 1)
    return z, need, overflow, rect_cells, closed_counts, opened_tot, proc_tot


def _phase2_exact_masked(plan: InterpolationPlan, qx_s, qy_s, alpha, over_q):
    """Per-block masked exact Phase 2 — the overflow arm of the blend.

    ``over_q (n_tot,)`` flags queries (sorted layout) whose approximated
    Phase 2 is unusable (near gather or level table truncated).  Instead of
    the old whole-batch ``lax.cond`` full sweep, each ``block_q`` run with
    at least one flagged query gets its OWN full-data sweep — a
    ``fori_loop`` whose per-block ``cond`` skips clean blocks, so one
    overflowing block costs O(block_q * m), not O(n * m) (the ``grid_knn
    (active=)`` discipline applied to Phase 2).  Per-block single calls of
    :func:`phase2_weights_full` are bit-identical to the corresponding
    blocks of a whole-batch call (the kernel is block-parallel), which the
    overflow bitwise tests pin.  Unswept blocks return 0 — callers blend
    through ``jnp.where(over_q, ...)``.
    """
    bq = plan.block_q
    n_tot = qx_s.shape[0]
    nb = n_tot // bq
    dtype = qx_s.dtype
    dxp, dyp, dzp = plan.data
    over_blk = jnp.any(over_q.reshape(nb, bq), axis=1)
    qx2 = qx_s.reshape(nb, bq)
    qy2 = qy_s.reshape(nb, bq)
    al2 = alpha.reshape(nb, bq)

    def sweep(b):
        qxb = jax.lax.dynamic_slice(qx2, (b, 0), (1, bq)).reshape(bq)
        qyb = jax.lax.dynamic_slice(qy2, (b, 0), (1, bq)).reshape(bq)
        alb = jax.lax.dynamic_slice(al2, (b, 0), (1, bq)).reshape(bq, 1)
        return phase2_weights_full(
            qxb, qyb, alb, dxp, dyp, dzp,
            eps=plan.params.exact_hit_eps, block_q=bq,
            block_d=plan.block_d, interpret=plan.interpret,
        )

    def body(b, z):
        zb = jax.lax.cond(over_blk[b], lambda: sweep(b),
                          lambda: jnp.zeros((bq, 1), dtype))
        return jax.lax.dynamic_update_slice(z, zb, (b * bq, 0))

    return jax.lax.fori_loop(0, nb, body, jnp.zeros((n_tot, 1), dtype))


def _execute_grid(plan: InterpolationPlan, qx, qy):
    grid = plan.grid
    params = plan.params
    n = qx.shape[0]
    dtype = qx.dtype

    # Morton-sort queries so each block's home cells form a compact patch,
    # pad the tail by repetition (adds no candidate cells)
    cx, cy = cell_of(grid, qx, qy)
    order = jnp.argsort(morton_ids(cx, cy), stable=True)
    n_pad = (-n) % plan.block_q
    qx_s = pad_tail(qx[order], n_pad)
    qy_s = pad_tail(qy[order], n_pad)
    cx_s, cy_s = cell_of(grid, qx_s, qy_s)

    # Phase-1 view: seam-split blocks (rectangles can't straddle a Morton
    # seam, the measured overflow worst case); pad slots repeat a real query
    qx_v, qy_v, cx_v, cy_v, src, dest = _seam_split_layout(plan, qx_s, qy_s, cx_s, cy_s)

    # containment-safe radii: plan-time table + closed-form overhang term
    r_need = plan.r_need[cy_v, cx_v]
    r_safe = safe_radius_from_need(grid, qx_v, qy_v, cx_v, cy_v, r_need)
    xlo, xhi, ylo, yhi = block_rectangles(grid, cx_v, cy_v, r_safe, plan.block_q)
    cand_x, cand_y, need = gather_candidates_csr(
        grid, xlo, xhi, ylo, yhi, plan.cand_capacity
    )

    # Phase 1, always on the kernel path: the per-block tile table clamps
    # each block's walk to its own non-sentinel tiles ("prefetch"), and an
    # overflowing block simply computes a (cheap, discarded) alpha from its
    # first `cand_capacity` candidates
    n_tiles_static = plan.cand_capacity // plan.cand_block_d
    # always the prefetch-style count: the dense pipeline ignores it but the
    # skipped_tile_fraction diagnostic reports what the launch WOULD skip
    num_tiles = _tile_table(need, plan.cand_capacity, plan.cand_block_d,
                            "prefetch")
    alpha_fast = phase1_alpha_from_candidates(
        qx_v, qy_v, cand_x, cand_y,
        params=params, area=plan.area, m_real=plan.m,
        block_q=plan.block_q, block_d=plan.cand_block_d,
        interpret=plan.interpret,
        num_tiles=num_tiles if plan.pipeline == "prefetch" else None,
    )

    # Per-block overflow blend: back in the sorted layout, ring-search ONLY
    # queries whose block overflowed (masked — a clean batch adds zero loop
    # iterations) and keep the kernel alpha everywhere else.  Exactness is
    # per query: kernel where covered, ring search where not.
    over_b = need > plan.cand_capacity
    over_v = jnp.repeat(over_b, plan.block_q)
    if dest is not None:
        alpha_fast = alpha_fast[dest]
        over_q = over_v[dest]
    else:
        over_q = over_v
    r_obs = grid_r_obs(grid, qx_s, qy_s, params.k, active=over_q)
    alpha_exact = adaptive_alpha(r_obs, plan.m, plan.area, params).astype(dtype)[:, None]
    alpha = jnp.where(over_q[:, None], alpha_exact, alpha_fast)

    dxp, dyp, dzp = plan.data
    qt_diag = None
    if plan.phase2 in ("farfield", "quadtree"):
        # approximated Phase 2 runs in the seam-split view (its rectangles
        # must not straddle Morton seams either); alpha maps in through src,
        # the per-slot z maps back through dest.  Blocks whose near field
        # overflows p2_capacity — or, for the quadtree, whose closed-node
        # table overflows its level capacity — would violate the error
        # bound (truncated sweep), so their queries take the per-block
        # masked exact sweep instead: one overflowing block costs
        # O(block_q * m), a clean batch costs nothing.
        alpha_v = alpha[src] if src is not None else alpha
        if plan.phase2 == "quadtree":
            (z_v, need2, over2_b, rect_cells, closed_counts, opened_tot,
             proc_tot) = _phase2_quadtree(plan, qx_v, qy_v, alpha_v, cx_v, cy_v)
            qt_diag = (closed_counts, opened_tot, proc_tot)
        else:
            z_v, need2, rect_cells = _phase2_farfield(plan, qx_v, qy_v,
                                                      alpha_v, cx_v, cy_v)
            over2_b = need2 > plan.p2_capacity
        over2_v = jnp.repeat(over2_b, plan.block_q)
        if dest is not None:
            z_near = z_v[dest]
            over2_s = over2_v[dest]
        else:
            z_near = z_v
            over2_s = over2_v
        z_full = _phase2_exact_masked(plan, qx_s, qy_s, alpha, over2_s)
        zhat = jnp.where(over2_s[:, None], z_full, z_near)
    else:
        zhat = phase2_weights_full(
            qx_s, qy_s, alpha, dxp, dyp, dzp,
            eps=params.exact_hit_eps, block_q=plan.block_q, block_d=plan.block_d,
            interpret=plan.interpret,
        )
    inv = jnp.argsort(order)
    # diagnostics count only blocks holding at least one real query — seam
    # pad blocks (all-duplicate, ~1 tile) would otherwise inflate the skip
    # fraction and the overflow-block count
    nb = need.shape[0]
    if dest is not None:
        real_slot = jnp.zeros((nb * plan.block_q,), bool).at[dest].set(True)
        real_b = jnp.any(real_slot.reshape(nb, plan.block_q), axis=1)
    else:
        real_b = jnp.ones((nb,), bool)
    n_real_tiles = jnp.maximum(jnp.sum(real_b.astype(jnp.int32)) * n_tiles_static, 1)
    stats = {
        # every real query took the ring path — the batch got no kernel help
        "grid_fallback": jnp.all(over_q[:n]),
        "cand_need_max": jnp.max(need),
        "overflow_blocks": jnp.sum((over_b & real_b).astype(jnp.int32)),
        "overflow_queries": jnp.sum(over_q[:n].astype(jnp.int32)),
        "overflow_query_mask": over_q[:n][inv],
        "skipped_tile_fraction": 1.0
        - jnp.sum(jnp.where(real_b, num_tiles, 0)).astype(jnp.float32) / n_real_tiles,
    }
    if plan.phase2 in ("farfield", "quadtree"):
        n_real_b = jnp.maximum(jnp.sum(real_b.astype(jnp.int32)), 1).astype(jnp.float32)
        if plan.phase2 == "quadtree":
            # far work per block is the number of CLOSED nodes summed over
            # levels — the quantity the O(log m) sweep benchmark tracks
            closed_counts, opened_tot, proc_tot = qt_diag
            closed_stack = jnp.stack(closed_counts)           # (n_levels, nb)
            far_terms = jnp.sum(closed_stack, axis=0)
            far_mean = jnp.sum(
                jnp.where(real_b, far_terms, 0)).astype(jnp.float32) / n_real_b
            stats.update({
                "cells_per_level": jnp.sum(
                    jnp.where(real_b[None, :], closed_stack, 0), axis=1
                ).astype(jnp.float32) / n_real_b,
                "opened_fraction": jnp.sum(
                    jnp.where(real_b, opened_tot, 0)).astype(jnp.float32)
                / jnp.maximum(jnp.sum(jnp.where(real_b, proc_tot, 0)), 1
                              ).astype(jnp.float32),
                "quadtree_rtol_bound": plan.farfield_bound,
            })
        else:
            far_mean = jnp.sum(
                jnp.where(real_b, grid.n_cells - rect_cells, 0)
            ).astype(jnp.float32) / n_real_b
            stats["farfield_rtol_bound"] = plan.farfield_bound
        stats.update({
            "near_points_mean": jnp.sum(
                jnp.where(real_b, need2, 0)).astype(jnp.float32) / n_real_b,
            "far_cells_mean": far_mean,
            "p2_overflow_queries": jnp.sum(over2_s[:n].astype(jnp.int32)),
        })
    return zhat[:n, 0][inv], alpha[:n, 0][inv], stats


def _execute_dense(plan: InterpolationPlan, qx, qy):
    params = plan.params
    n = qx.shape[0]
    dtype = qx.dtype
    zero = jnp.zeros((), dtype)
    qxp = pad_to(qx, plan.block_q, zero)
    qyp = pad_to(qy, plan.block_q, zero)
    kw = dict(params=params, area=plan.area, m_real=plan.m, interpret=plan.interpret)
    stats = {}

    if plan.layout == "aoas":
        (data,) = plan.data
        qx2, qy2 = qxp[None, :], qyp[None, :]
        if plan.impl == "naive":
            z, a = aidw_naive_aoas(data, qx2, qy2, block_q=plan.block_q, **kw)
        else:  # tiled (build_plan rejects the rest for aoas)
            z, a = aidw_tiled_aoas(
                data, qx2, qy2, block_q=plan.block_q, block_d=plan.block_d, **kw
            )
        return z[0, :n], a[0, :n], stats

    dx2, dy2, dz2 = plan.data
    qx2, qy2 = qxp[:, None], qyp[:, None]
    if plan.impl == "naive":
        z, a = aidw_naive_soa(dx2, dy2, dz2, qx2, qy2, block_q=plan.block_q, **kw)
    elif plan.impl == "tiled":
        z, a = aidw_tiled_soa(
            dx2, dy2, dz2, qx2, qy2, block_q=plan.block_q, block_d=plan.block_d, **kw
        )
    elif plan.impl == "binned":
        # nbins: power-of-two divisor of block_d near 6k (see DESIGN.md §3)
        nbins = 16
        while nbins * 2 <= min(6 * params.k, plan.block_d // 4):
            nbins *= 2
        z, a = aidw_tiled_soa(
            dx2, dy2, dz2, qx2, qy2, block_q=plan.block_q, block_d=plan.block_d,
            nbins=nbins, **kw,
        )
    elif plan.impl == "fused":
        z, a = aidw_fused_soa(
            dx2, dy2, dz2, qx2, qy2, block_q=plan.block_q, block_d=plan.block_d, **kw
        )
    else:  # tiled_v2: threshold-skip kNN pass + measured merge fraction
        z, a, merges = aidw_tiled_v2_soa(
            dx2, dy2, dz2, qx2, qy2, block_q=plan.block_q, block_d=plan.block_d, **kw
        )
        n_tiles = dx2.shape[1] // plan.block_d
        stats = {
            "merge_fraction": jnp.sum(merges).astype(jnp.float32)
            / (merges.shape[0] * n_tiles)
        }
    return z[:n, 0], a[:n, 0], stats


def _execute_idw(plan: InterpolationPlan, qx, qy):
    n = qx.shape[0]
    dtype = qx.dtype
    zero = jnp.zeros((), dtype)
    qx2 = pad_to(qx, plan.block_q, zero)[:, None]
    qy2 = pad_to(qy, plan.block_q, zero)[:, None]
    dx2, dy2, dz2 = plan.data
    z = idw_tiled_soa(
        dx2, dy2, dz2, qx2, qy2, alpha=plan.idw_alpha,
        block_q=plan.block_q, block_d=plan.block_d, interpret=plan.interpret,
    )
    alpha = jnp.full((n,), plan.idw_alpha, dtype)
    return z[:n, 0], alpha, {}


def _execute_chunked(plan: InterpolationPlan, qx, qy):
    dx, dy, dz = plan.data
    params = plan.params
    if plan.knn == "grid":
        r_obs = grid_r_obs(plan.grid, qx, qy, params.k)
    else:
        r_obs = brute_r_obs(
            dx, dy, qx, qy, params.k, q_chunk=plan.q_chunk, d_chunk=plan.d_chunk
        )
    alpha = adaptive_alpha(r_obs, plan.m, plan.area, params)
    zhat = _interpolate_pass2(
        dx, dy, dz, qx, qy, alpha, params,
        area=plan.area, q_chunk=plan.q_chunk, d_chunk=plan.d_chunk,
    )
    return zhat, alpha, {}


def _execute(plan: InterpolationPlan, qx, qy):
    # Input hardening: a NaN/Inf query coordinate would otherwise flow
    # through the kernel min-reductions into a silently wrong (finite) alpha
    # and z.  Replace non-finite queries with an in-bbox dummy for the
    # compute (so they cannot distort block rectangles or capacities
    # either) and NaN-mask their outputs — NaN in, NaN out.
    qx = jnp.asarray(qx)
    qy = jnp.asarray(qy)
    bad = ~(jnp.isfinite(qx) & jnp.isfinite(qy))
    zero = jnp.zeros((), qx.dtype)
    qx = jnp.where(bad, zero, qx)
    qy = jnp.where(bad, zero, qy)
    if plan.impl == "grid":
        z, a, stats = _execute_grid(plan, qx, qy)
    elif plan.impl == "idw":
        z, a, stats = _execute_idw(plan, qx, qy)
    elif plan.impl == "chunked":
        z, a, stats = _execute_chunked(plan, qx, qy)
    else:
        z, a, stats = _execute_dense(plan, qx, qy)
    nan = jnp.asarray(jnp.nan, z.dtype)
    return jnp.where(bad, nan, z), jnp.where(bad, nan, a), stats


@jax.jit
def execute(plan: InterpolationPlan, qx, qy):
    """Interpolate one query batch against a prebuilt plan.

    Pure and jit-compatible for every impl (the plan's statics live in the
    pytree aux data, so they are trace-time constants).  Returns
    ``(z_hat, alpha)``, shape ``(n,)`` each, in caller query order.

    Non-finite query coordinates are hardened: a query with a NaN/Inf in
    either coordinate yields NaN ``z_hat`` and NaN ``alpha`` (never a
    silently wrong finite value), and the finite queries in the same batch
    are computed exactly as if the bad slots held in-bbox dummies.
    """
    z, a, _ = _execute(plan, qx, qy)
    return z, a


@jax.jit
def _execute_with_stats_jit(plan: InterpolationPlan, qx, qy):
    return _execute(plan, qx, qy)


# ---- persistent-overflow tracking (ROADMAP capacity-model item) -------------
# The plan's static candidate capacity is sized from an *assumed* serving
# density (`query_occupancy`); a workload that is persistently sparser keeps
# paying the exact ring-search arm batch after batch.  execute_with_stats
# counts, per plan object, the consecutive diagnostic batches with
# overflow_queries > 0 and surfaces `persistent_overflow` (plus a one-shot
# RuntimeWarning) once the streak reaches the threshold — the hook a future
# per-batch capacity re-estimator will replace with an automatic re-plan.
PERSISTENT_OVERFLOW_BATCHES = 3
_overflow_streaks: dict[int, int] = {}
_overflow_lock = threading.Lock()


def _note_overflow(plan: InterpolationPlan, n_overflow: int) -> bool:
    key = id(plan)
    with _overflow_lock:
        if key not in _overflow_streaks:
            weakref.finalize(plan, _overflow_streaks.pop, key, None)
        streak = _overflow_streaks.get(key, 0) + 1 if n_overflow > 0 else 0
        _overflow_streaks[key] = streak
    if streak == PERSISTENT_OVERFLOW_BATCHES:
        warnings.warn(
            f"overflow_queries > 0 for {streak} consecutive batches against "
            "this plan: the static candidate capacity looks undersized for "
            "the serving workload (results stay exact via the ring-search "
            "blend, but at ring-search cost). Consider re-planning with a "
            "lower query_occupancy= or a coarser grid — or serve through "
            "repro.serving.CapacityReestimator, which re-plans and swaps "
            "automatically.",
            CapacityOverflowWarning,
            stacklevel=3,
        )
    return streak >= PERSISTENT_OVERFLOW_BATCHES


def execute_with_stats(plan: InterpolationPlan, qx, qy):
    """Like :func:`execute` but also returns the impl's diagnostics.

    ``grid``: ``overflow_blocks`` / ``overflow_queries`` (how much of the
    batch exceeded the plan's static candidate capacity and took the exact
    masked ring-search arm of the blend), ``overflow_query_mask`` (bool
    ``(n,)``, caller order — which queries those were),
    ``skipped_tile_fraction`` (share of Phase-1 candidate-tile steps the
    scalar-prefetch pipeline skipped as all-sentinel), ``cand_need_max``,
    ``grid_fallback`` (bool — EVERY query overflowed, i.e. the batch got no
    kernel fast path at all; single blocks overflowing no longer drag the
    batch down), and ``persistent_overflow`` (host-side bool — overflow has
    now persisted for ``PERSISTENT_OVERFLOW_BATCHES`` consecutive diagnostic
    batches against this plan object; a RuntimeWarning suggesting a re-plan
    fires when the streak is first reached).  ``grid`` with
    ``phase2="farfield"`` additionally reports ``near_points_mean`` /
    ``far_cells_mean`` (per real query block), the plan's proved
    ``farfield_rtol_bound``, and ``p2_overflow_queries`` (queries routed to
    the exact Phase-2 sweep because their block's near gather overflowed).
    ``grid`` with ``phase2="quadtree"`` reports the same near/overflow keys
    plus ``far_cells_mean`` (mean CLOSED nodes per real block, summed over
    levels — the ~O(log m) quantity), ``cells_per_level`` (its per-level
    split, shape ``(n_levels,)``), ``opened_fraction`` (opened / processed
    nonempty nodes — how much of the tree the walk descends) and the
    plan's proved ``quadtree_rtol_bound``; the dict structure is static per
    plan (the level count is a plan static).
    ``tiled_v2``: the measured ``merge_fraction``.
    The computation is jitted with a static dict structure per plan (no
    retrace across same-shape batches); only the streak bookkeeping runs on
    the host, which is why this entry — unlike :func:`execute` — syncs on
    ``overflow_queries``."""
    z, a, stats = _execute_with_stats_jit(plan, qx, qy)
    # Under an OUTER jit the call inlines and the stats are tracers: the
    # host-side streak bookkeeping cannot (and should not) run there — the
    # dict then simply lacks the persistent_overflow key, exactly the
    # pre-tracking behaviour, instead of raising on int(tracer).
    if plan.impl == "grid" and not isinstance(
        stats["overflow_queries"], jax.core.Tracer
    ):
        stats = dict(stats)
        stats["persistent_overflow"] = _note_overflow(
            plan, int(stats["overflow_queries"])
        )
    return z, a, stats


# the no-retrace contract is asserted against the underlying jit cache
execute_with_stats._cache_size = _execute_with_stats_jit._cache_size
