"""Execute — the traced half of the plan/execute engine.

``execute(plan, qx, qy)`` is a pure function of its arguments for every
impl.  All shapes inside are fixed by (plan statics, query count), so the
jitted entry points compile once per (plan configuration, query shape) and
hit the cache on every further batch — the "build once, execute per
request" serving shape.

The grid path (DESIGN.md §6) runs entirely under the trace: Morton sort,
per-query safe radii from the plan's ``required_radius`` table (closed form
— no while-loop), the static-capacity CSR candidate gather, Phase 1 over
candidate rows and the full-data Phase 2.  Exactness is unconditional: when
a query batch needs more candidates than the plan's capacity (far
out-of-bbox queries, query distributions unlike the data), a ``lax.cond``
switches Phase 1 to the exact expanding-ring search — slower, never wrong.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.aidw import _interpolate_pass2, adaptive_alpha, brute_r_obs
from repro.core.grid import cell_of, grid_r_obs, morton_ids, safe_radius_from_need
from repro.core.layouts import pad_tail, pad_to
from repro.engine.plan import InterpolationPlan
from repro.kernels.aidw_fused import aidw_fused_soa
from repro.kernels.aidw_grid import (
    block_rectangles,
    gather_candidates_csr,
    phase1_alpha_from_candidates,
    phase2_weights_full,
)
from repro.kernels.aidw_naive import aidw_naive_aoas, aidw_naive_soa
from repro.kernels.aidw_tiled import aidw_tiled_aoas, aidw_tiled_soa
from repro.kernels.aidw_tiled_v2 import aidw_tiled_v2_soa
from repro.kernels.idw_tiled import idw_tiled_soa


def _execute_grid(plan: InterpolationPlan, qx, qy):
    grid = plan.grid
    params = plan.params
    n = qx.shape[0]
    dtype = qx.dtype

    # Morton-sort queries so each block's home cells form a compact patch,
    # pad the tail by repetition (adds no candidate cells)
    cx, cy = cell_of(grid, qx, qy)
    order = jnp.argsort(morton_ids(cx, cy), stable=True)
    n_pad = (-n) % plan.block_q
    qx_s = pad_tail(qx[order], n_pad)
    qy_s = pad_tail(qy[order], n_pad)
    cx_s, cy_s = cell_of(grid, qx_s, qy_s)

    # containment-safe radii: plan-time table + closed-form overhang term
    r_need = plan.r_need[cy_s, cx_s]
    r_safe = safe_radius_from_need(grid, qx_s, qy_s, cx_s, cy_s, r_need)
    xlo, xhi, ylo, yhi = block_rectangles(grid, cx_s, cy_s, r_safe, plan.block_q)
    cand_x, cand_y, need = gather_candidates_csr(
        grid, xlo, xhi, ylo, yhi, plan.cand_capacity
    )
    overflow = jnp.any(need > plan.cand_capacity)

    def _phase1_fast(_):
        return phase1_alpha_from_candidates(
            qx_s, qy_s, cand_x, cand_y,
            params=params, area=plan.area, m_real=plan.m,
            block_q=plan.block_q, block_d=plan.cand_block_d,
            interpret=plan.interpret,
        )

    def _phase1_exact(_):
        r_obs = grid_r_obs(grid, qx_s, qy_s, params.k)
        return adaptive_alpha(r_obs, plan.m, plan.area, params).astype(dtype)[:, None]

    alpha = jax.lax.cond(overflow, _phase1_exact, _phase1_fast, None)

    dxp, dyp, dzp = plan.data
    zhat = phase2_weights_full(
        qx_s, qy_s, alpha, dxp, dyp, dzp,
        eps=params.exact_hit_eps, block_q=plan.block_q, block_d=plan.block_d,
        interpret=plan.interpret,
    )
    inv = jnp.argsort(order)
    stats = {"grid_fallback": overflow, "cand_need_max": jnp.max(need)}
    return zhat[:n, 0][inv], alpha[:n, 0][inv], stats


def _execute_dense(plan: InterpolationPlan, qx, qy):
    params = plan.params
    n = qx.shape[0]
    dtype = qx.dtype
    zero = jnp.zeros((), dtype)
    qxp = pad_to(qx, plan.block_q, zero)
    qyp = pad_to(qy, plan.block_q, zero)
    kw = dict(params=params, area=plan.area, m_real=plan.m, interpret=plan.interpret)
    stats = {}

    if plan.layout == "aoas":
        (data,) = plan.data
        qx2, qy2 = qxp[None, :], qyp[None, :]
        if plan.impl == "naive":
            z, a = aidw_naive_aoas(data, qx2, qy2, block_q=plan.block_q, **kw)
        else:  # tiled (build_plan rejects the rest for aoas)
            z, a = aidw_tiled_aoas(
                data, qx2, qy2, block_q=plan.block_q, block_d=plan.block_d, **kw
            )
        return z[0, :n], a[0, :n], stats

    dx2, dy2, dz2 = plan.data
    qx2, qy2 = qxp[:, None], qyp[:, None]
    if plan.impl == "naive":
        z, a = aidw_naive_soa(dx2, dy2, dz2, qx2, qy2, block_q=plan.block_q, **kw)
    elif plan.impl == "tiled":
        z, a = aidw_tiled_soa(
            dx2, dy2, dz2, qx2, qy2, block_q=plan.block_q, block_d=plan.block_d, **kw
        )
    elif plan.impl == "binned":
        # nbins: power-of-two divisor of block_d near 6k (see DESIGN.md §3)
        nbins = 16
        while nbins * 2 <= min(6 * params.k, plan.block_d // 4):
            nbins *= 2
        z, a = aidw_tiled_soa(
            dx2, dy2, dz2, qx2, qy2, block_q=plan.block_q, block_d=plan.block_d,
            nbins=nbins, **kw,
        )
    elif plan.impl == "fused":
        z, a = aidw_fused_soa(
            dx2, dy2, dz2, qx2, qy2, block_q=plan.block_q, block_d=plan.block_d, **kw
        )
    else:  # tiled_v2: threshold-skip kNN pass + measured merge fraction
        z, a, merges = aidw_tiled_v2_soa(
            dx2, dy2, dz2, qx2, qy2, block_q=plan.block_q, block_d=plan.block_d, **kw
        )
        n_tiles = dx2.shape[1] // plan.block_d
        stats = {
            "merge_fraction": jnp.sum(merges).astype(jnp.float32)
            / (merges.shape[0] * n_tiles)
        }
    return z[:n, 0], a[:n, 0], stats


def _execute_idw(plan: InterpolationPlan, qx, qy):
    n = qx.shape[0]
    dtype = qx.dtype
    zero = jnp.zeros((), dtype)
    qx2 = pad_to(qx, plan.block_q, zero)[:, None]
    qy2 = pad_to(qy, plan.block_q, zero)[:, None]
    dx2, dy2, dz2 = plan.data
    z = idw_tiled_soa(
        dx2, dy2, dz2, qx2, qy2, alpha=plan.idw_alpha,
        block_q=plan.block_q, block_d=plan.block_d, interpret=plan.interpret,
    )
    alpha = jnp.full((n,), plan.idw_alpha, dtype)
    return z[:n, 0], alpha, {}


def _execute_chunked(plan: InterpolationPlan, qx, qy):
    dx, dy, dz = plan.data
    params = plan.params
    if plan.knn == "grid":
        r_obs = grid_r_obs(plan.grid, qx, qy, params.k)
    else:
        r_obs = brute_r_obs(
            dx, dy, qx, qy, params.k, q_chunk=plan.q_chunk, d_chunk=plan.d_chunk
        )
    alpha = adaptive_alpha(r_obs, plan.m, plan.area, params)
    zhat = _interpolate_pass2(
        dx, dy, dz, qx, qy, alpha, params,
        area=plan.area, q_chunk=plan.q_chunk, d_chunk=plan.d_chunk,
    )
    return zhat, alpha, {}


def _execute(plan: InterpolationPlan, qx, qy):
    if plan.impl == "grid":
        return _execute_grid(plan, qx, qy)
    if plan.impl == "idw":
        return _execute_idw(plan, qx, qy)
    if plan.impl == "chunked":
        return _execute_chunked(plan, qx, qy)
    return _execute_dense(plan, qx, qy)


@jax.jit
def execute(plan: InterpolationPlan, qx, qy):
    """Interpolate one query batch against a prebuilt plan.

    Pure and jit-compatible for every impl (the plan's statics live in the
    pytree aux data, so they are trace-time constants).  Returns
    ``(z_hat, alpha)``, shape ``(n,)`` each, in caller query order.
    """
    z, a, _ = _execute(plan, qx, qy)
    return z, a


@jax.jit
def execute_with_stats(plan: InterpolationPlan, qx, qy):
    """Like :func:`execute` but also returns the impl's diagnostics:
    ``grid``: ``grid_fallback`` (bool — this batch exceeded the plan's
    static candidate capacity and took the exact ring-search path) and
    ``cand_need_max``; ``tiled_v2``: the measured ``merge_fraction``.
    The dict's *structure* is static per plan, so this jits identically."""
    return _execute(plan, qx, qy)
