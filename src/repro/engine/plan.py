"""Plan construction — the eager half of the plan/execute engine.

A plan captures, once per dataset, every decision that would otherwise leak
data-dependent *shapes* into the hot path:

* padded data layouts (sentinel coordinates, block-multiple widths, the
  SoA/AoaS transform) for the dense kernel family;
* the grid impl's **static-shape snapshot**: the :class:`UniformGrid` (with
  its CSR point arrays), the per-cell ``required_radius`` table, and a fixed
  candidate capacity chosen from the occupancy histogram — including the
  per-workload ``block_d`` autotune and the pathological-resolution
  warn-or-rebuild loop (ROADMAP item);
* chunk sizes / constant powers for the pure-jnp and IDW paths.

Everything a plan stores is either a static (hashable aux data of the
pytree, a trace-time constant) or an array child, so ``execute(plan, ...)``
jits with the plan as an ordinary argument and two same-shape query batches
against one plan hit the same executable.  Plan construction is eager by
design for ``impl="grid"`` (capacities are concrete ints); the ``chunked``
brute path builds traceable plans so the distributed sharded path can plan
inside ``shard_map``.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.aidw import AIDWParams
from repro.core.grid import (
    DEFAULT_OCCUPANCY,
    UniformGrid,
    build_grid,
    cell_aggregates,
    quadtree_aggregates,
    required_radius_table,
    static_cell_radius,
)
from repro.core.layouts import coord_sentinel, pad_to, soa_to_aoas
from repro.errors import PathologicalGridWarning, UnprovableRtolWarning

Impl = Literal["naive", "tiled", "binned", "fused", "grid", "tiled_v2", "idw", "chunked"]
Layout = Literal["soa", "aoas"]

_DENSE_IMPLS = ("naive", "tiled", "binned", "fused", "tiled_v2")
_SOA_ONLY = ("binned", "fused", "grid", "tiled_v2", "idw", "chunked")

# Rebuild threshold: a resolution is "pathological" when some cell needs a
# safe ring radius beyond this — the signature of a grid too fine for its
# data (clustered points leave most cells empty, so ``required_radius``
# explodes in the voids and candidate rectangles approach a full sweep).
# A well-sized grid sits at r_safe ~ 2-3 (see ``static_cell_radius``).
_MAX_SAFE_RADIUS = 6
_MAX_REBUILDS = 3

# Far-field fallback: when the requested rtol is unprovable at any
# profitable radius, take the cheapest radius proving at least this bound
# (worst-case relative error above ~half the data scale promises nothing).
_FALLBACK_BOUND_CEIL = 0.5

# Per-tile element budget for the Phase-2 near/far sweeps: block_q * tile_d
# capped so the in-kernel (block_q, tile_d) f32 distance tile stays ~1 MiB.
_P2_TILE_ELEMS = 64 * 4096


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return bool(interpret)
    return jax.default_backend() != "tpu"


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class InterpolationPlan:
    """Everything needed to interpolate any number of query batches.

    Static fields (pytree aux — trace-time constants, part of the jit cache
    key) vs array children (``data``, ``grid``, ``r_need``) are split so the
    whole plan passes through ``jax.jit`` as one argument.
    """

    # --- static ---
    impl: str
    layout: str
    params: AIDWParams
    area: float
    m: int                    # real (unpadded) data-point count
    block_q: int
    block_d: int              # data-axis tile: dense sweep / grid Phase 2
    interpret: bool
    knn: str                  # chunked: "brute" | "grid"
    q_chunk: int
    d_chunk: int
    idw_alpha: float
    cand_capacity: int        # grid: static candidate-row width (points)
    cand_block_d: int         # grid: Phase-1 candidate tile (autotuned)
    grid_rebuilds: int        # grid: coarsening rebuilds during planning
    seam_level: int           # grid: Morton quadrant split depth (0 = off)
    pipeline: str             # grid Phase 1: "prefetch" (tile-skip) | "dense"
    phase2: str               # grid Phase 2: "exact" (full sweep) | "farfield"
    farfield_rtol: float      # farfield: user-requested relative error target
    farfield_radius: int      # far field/quadtree: near-field radius (cells)
    farfield_bound: float     # far field/quadtree: proved worst-case rel error
    p2_capacity: int          # farfield: static near-field candidate width
    p2_block_d: int           # farfield: near-field sweep tile
    p2_far_block_d: int       # farfield: far cell-aggregate sweep tile
    qt_tau: float             # quadtree: effective opening ratio tau_eff
    qt_levels: tuple          # quadtree: per-level (nx, ny, step, k_pad, tile)
    # --- children ---
    data: tuple               # impl-specific padded arrays
    grid: UniformGrid | None
    r_need: jnp.ndarray | None  # (gy, gx) int32 per-cell required_radius
    far: tuple                # farfield: padded (1, ncp) cell-aggregate arrays
                              # quadtree: per-level node-aggregate tuples

    def tree_flatten(self):
        aux = (self.impl, self.layout, self.params, self.area, self.m,
               self.block_q, self.block_d, self.interpret, self.knn,
               self.q_chunk, self.d_chunk, self.idw_alpha,
               self.cand_capacity, self.cand_block_d, self.grid_rebuilds,
               self.seam_level, self.pipeline, self.phase2,
               self.farfield_rtol, self.farfield_radius, self.farfield_bound,
               self.p2_capacity, self.p2_block_d, self.p2_far_block_d,
               self.qt_tau, self.qt_levels)
        return (self.data, self.grid, self.r_need, self.far), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, grid, r_need, far = children
        return cls(*aux, data=data, grid=grid, r_need=r_need, far=far)


def _choose_candidate_capacity(grid: UniformGrid, r_need, block_q: int, m: int,
                               query_occupancy: float | None):
    """Static candidate capacity (points) from the occupancy histogram.

    A block of ``block_q`` Morton-contiguous queries at ~``query_occupancy``
    queries per cell spans a home-cell bbox of side about
    ``2*ceil(sqrt(block_q / query_occupancy))`` (a contiguous Morton run of
    L cells fits a box of side <= 2*ceil(sqrt(L))); expanding by the
    grid-max in-cell safe radius bounds the rectangle side ``W``.  The
    capacity is the densest WxW occupancy window (one integral-image sweep).
    Query density is unknowable at plan time, so the default assumes serving
    batches ~4x sparser than the data; blocks that exceed the capacity at
    execute time (sparser/far-out-of-bbox batches) take the exact
    ring-search fallback instead of losing neighbours.

    Returns ``(capacity, r_static, window, side)`` — all concrete ints
    (``side`` is reused to size the farfield near-field capacity with the
    same block-bbox model).
    """
    r_cell = static_cell_radius(grid, r_need)
    r_static = int(jnp.max(r_cell))
    occ_mean = max(m / max(grid.n_cells, 1), 1.0)
    if query_occupancy is None:
        query_occupancy = occ_mean / 4.0
    query_occupancy = max(query_occupancy, 0.5)
    side = 2 * math.ceil(math.sqrt(block_q / query_occupancy))
    window = min(side + 2 * r_static + 1, max(grid.gx, grid.gy))
    capacity = _densest_window_count(grid, window)
    return capacity, r_static, window, side


def _densest_window_count(grid: UniformGrid, window: int) -> int:
    """Max point count of any ``window x window`` cell block — one
    integral-image sweep, concrete int."""
    c = grid.cum
    ys = jnp.minimum(jnp.arange(grid.gy, dtype=jnp.int32) + window, grid.gy)
    xs = jnp.minimum(jnp.arange(grid.gx, dtype=jnp.int32) + window, grid.gx)
    y0 = jnp.arange(grid.gy, dtype=jnp.int32)
    x0 = jnp.arange(grid.gx, dtype=jnp.int32)
    sums = (c[ys[:, None], xs[None, :]] - c[y0[:, None], xs[None, :]]
            - c[ys[:, None], x0[None, :]] + c[y0[:, None], x0[None, :]])
    return max(int(jnp.max(sums)), 1)


def _farfield_bound_model(radius: int, cell_min: float, a_max: float,
                          e_max: float, z_dev_max: float, z_abs_max: float):
    """Worst-case relative error of the far-field Phase 2 at a given
    near-field radius — the provable half of the error budget (DESIGN.md §7).

    Geometry (the ring-search invariant, which survives out-of-bbox queries):
    every far cell — Chebyshev cell-distance ``>= radius + 1`` from the
    query's clamped home cell — has all its points, and therefore its
    centroid, at Euclidean distance ``d_c >= radius * cell_min``, with every
    point within ``e_max`` of the centroid.  Let ``tau = e_max / (radius *
    cell_min)`` (>= the per-cell dispersion ratio of every far cell) and
    ``A = max(alpha_levels)`` (every per-weight term below increases with
    alpha).

    Because the centroid zeroes the first moment of the cell's points, the
    count term ``n_c * w(d_c)`` matches ``sum_j w_j`` to SECOND order in the
    dispersion: Taylor with the Lagrange Hessian of ``w(p) = |q - p|^-a``
    (largest eigenvalue ``a*(a+1)*d^-a-2``, evaluated no closer than
    ``d_c - e``) gives

        |n w(d_c) - sum w_j| <= eps2 * n * w(d_c),
        eps2 = 0.5 * A * (A+1) * tau^2 * (1 - tau)^-(A+2).

    The z-sum term ``w(d_c) * S_c`` additionally pays a FIRST-order price
    for z varying inside the cell: splitting ``z_j = zbar_c + dz_j``,

        |w(d_c) S_c - sum w_j z_j| <= (|zbar_c| eps2 + eta * z_dev_max) * n * w(d_c),
        eta = (1 - tau)^-A - 1   (per-point weight spread at dispersion tau).

    With ``sum_cell w_j >= n w(d_c) (1+tau)^-A``, the exact interpolant a
    convex combination of data z (``|z| <= s = z_abs_max``), and the
    perturbed denominator ``>= (1 - eps2h) * D``:

        |z_ff - z| / s <= (2*eps2 + eta * z_dev_max/s) * (1+tau)^A / (1 - eps2h),
        eps2h = eps2 * (1+tau)^A.

    Returns ``inf`` when ``tau >= 1`` or ``eps2h >= 1`` (radius too small for
    any guarantee).  ``z_dev_max = 0`` (constant z per cell — e.g. one point
    per cell) collapses the model to the pure second-order term, and
    ``e_max = 0`` to exactly 0.
    """
    if radius <= 0:
        return math.inf
    tau = e_max / (radius * cell_min) if cell_min > 0 else math.inf
    g = z_dev_max / z_abs_max if z_abs_max > 0 else 0.0
    return _bound_from_tau(tau, a_max, g)


def _bound_from_tau(tau: float, a_max: float, g: float = 0.0,
                    dipole: bool = False):
    """The (tau, alpha) -> worst-case-relative-error core of the far-field
    models, shared by the single-level model above and the quadtree model
    (DESIGN.md §7-8).

    ``dipole=False`` is the PR-5 single-level budget: second-order count term
    plus the FIRST-order ``eta * g`` z-spread term (``g = z_dev_max /
    z_abs_max``).  ``dipole=True`` is the quadtree budget: the kernel adds
    the stored first z-moment term ``grad w(cent) . M``, which cancels the
    z budget's first-order piece exactly (the count term's first order
    already cancels because the centroid zeroes the first position moment),
    so BOTH terms are second-order in tau:

        |N_hat - N| <= eps2 * n * w(d) * z_abs_max,
        |D_hat - D| <= eps2 * n * w(d),
        bound = 2 * eps2 * (1+tau)^A / (1 - eps2 * (1+tau)^A).

    Monotone non-increasing as tau shrinks (the property the hypothesis
    test pins); ``inf`` when no guarantee exists at this tau.
    """
    if tau >= 1.0:
        return math.inf
    grow = (1.0 + tau) ** a_max
    eps2 = 0.5 * a_max * (a_max + 1.0) * tau * tau * (1.0 - tau) ** (-a_max - 2.0)
    eps2h = eps2 * grow
    if eps2h >= 1.0:
        return math.inf
    if dipole:
        return 2.0 * eps2 * grow / (1.0 - eps2h)
    eta = (1.0 - tau) ** (-a_max) - 1.0
    return (2.0 * eps2 + eta * g) * grow / (1.0 - eps2h)


def _bound_at_radius(grid: UniformGrid, params: AIDWParams, agg, radius: int):
    """Proved worst-case bound at a given near radius — the ONE source of
    truth shared by the auto chooser and the ``farfield_radius=`` override.
    A radius >= max(gx, gy) makes every near rectangle span the whole grid
    (the far set is empty), so the bound is exactly 0 there."""
    if radius >= max(grid.gx, grid.gy):
        return 0.0
    cell_min = float(jnp.minimum(grid.cell_size[0], grid.cell_size[1]))
    return _farfield_bound_model(radius, cell_min, float(max(params.alpha_levels)),
                                 agg.e_max, agg.z_dev_max, agg.z_abs_max)


def _choose_farfield_radius(grid: UniformGrid, params: AIDWParams,
                            farfield_rtol: float, agg, *, side: int, m: int):
    """Near-field radius from the worst-case error model + a cost cap.

    Returns ``(radius, bound)`` — concrete int/float.  Picks the smallest
    radius whose :func:`_farfield_bound_model` value meets ``farfield_rtol``,
    subject to a profitability cap: the modeled Phase-2 work (near window
    occupancy + one term per cell) must stay under ``m / 4``, else the
    far-field split would not beat the exact m-point sweep it replaces.  If
    the target is not provable under the cap — the common case for tight
    rtols, since a single-level aggregate's worst-case bound is second-order
    in (cell dispersion / near distance) and the worst query sits right at
    the near boundary — the cap radius is used and a warning reports the
    honest bound; measured error (``core.accuracy.farfield_error_report``)
    is typically orders of magnitude below it.  A radius beyond
    ``max(gx, gy)`` would make every near rectangle span the whole grid
    (the far set is empty and the "approximation" is the exact sweep with
    gather overhead), so radii are also clamped there, with bound 0.
    """
    cover = max(grid.gx, grid.gy)
    occ_mean = max(m / max(grid.n_cells, 1), 1.0)

    def modeled_cost(radius):
        window = min(side + 2 * radius + 1, cover)
        return window * window * occ_mean + grid.n_cells

    def bound_at(radius):
        return _bound_at_radius(grid, params, agg, radius)

    r_cap = 1
    while r_cap + 1 < cover and modeled_cost(r_cap + 1) <= m / 4:
        r_cap += 1
    for radius in range(1, r_cap + 1):
        bound = bound_at(radius)
        if bound <= farfield_rtol:
            return radius, bound
    # Not provable under the cap.  Fall back to the CHEAPEST radius whose
    # bound is at least non-vacuous (a relative-error promise above ~0.5 of
    # the data scale guarantees nothing useful, and larger radii buy only
    # marginally tighter worst cases at near-linear extra cost); r_cap if
    # even that is out of reach.
    radius = r_cap
    for r in range(1, r_cap + 1):
        if bound_at(r) <= _FALLBACK_BOUND_CEIL:
            radius = r
            break
    bound = bound_at(radius)
    warnings.warn(
        f"farfield_rtol={farfield_rtol:g} is not provable within the "
        f"profitable near-field budget (radius <= {r_cap} of a "
        f"{grid.gx}x{grid.gy} grid); using radius {radius} with worst-case "
        f"bound {bound:.3g}. Measured error is typically far below the "
        "bound — check farfield_error_report, or pass farfield_radius= / a "
        "coarser grid to trade speed for guarantee.",
        UnprovableRtolWarning,
        stacklevel=4,
    )
    return radius, bound


def _quadtree_tau_required(a_max: float, rtol: float) -> float:
    """Largest opening ratio tau whose dipole bound still proves ``rtol`` —
    bisection on the monotone :func:`_bound_from_tau` (60 steps ~ 1 ulp).
    To leading order ``tau_req ~ sqrt(rtol / (2 * a * (a+1)))``; at a = 4,
    rtol = 1e-3 that is ~7e-3 — an opening angle coarse data can actually
    meet, unlike the first-order single-level budget."""
    hi = 0.5
    if _bound_from_tau(hi, a_max, dipole=True) <= rtol:
        return hi
    lo = 0.0
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if _bound_from_tau(mid, a_max, dipole=True) <= rtol:
            lo = mid
        else:
            hi = mid
    return lo


def _choose_quadtree_radius(grid: UniformGrid, params: AIDWParams,
                            farfield_rtol: float, e0_max: float, *,
                            side: int, m: int):
    """Near-field radius + effective opening ratio for the quadtree arm.

    Returns ``(radius, tau_eff, bound)``.  The walk closes a node only when
    its own dispersion ``e`` fits ``tau_eff * (gap-1) * cell_min`` — EXCEPT
    level-0 cells, which cannot be opened further and are force-closed
    whenever their gap clears ``radius + 1``.  ``tau_eff`` therefore must
    also cover the worst level-0 cell at the near boundary:

        tau_eff = max(tau_req, e0_max / (radius * cell_min)).

    The smallest radius under the Phase-2 profitability cap whose
    ``_bound_from_tau(tau_eff, dipole=True)`` meets ``farfield_rtol`` wins;
    when even the cap radius cannot prove the target (cell dispersion too
    coarse — e.g. uniform data, where e0 ~ 0.7 * cell) the fallback mirrors
    :func:`_choose_farfield_radius`: cheapest non-vacuous radius + a warning
    with the honest bound.
    """
    a_max = float(max(params.alpha_levels))
    cell_min = float(jnp.minimum(grid.cell_size[0], grid.cell_size[1]))
    cover = max(grid.gx, grid.gy)
    occ_mean = max(m / max(grid.n_cells, 1), 1.0)
    tau_req = _quadtree_tau_required(a_max, farfield_rtol)

    def at_radius(radius):
        if radius >= cover:
            return tau_req, 0.0
        if cell_min <= 0:
            return math.inf, math.inf
        tau_eff = max(tau_req, e0_max / (radius * cell_min))
        return tau_eff, _bound_from_tau(tau_eff, a_max, dipole=True)

    def modeled_cost(radius):
        window = min(side + 2 * radius + 1, cover)
        return window * window * occ_mean

    r_cap = 1
    while r_cap + 1 < cover and modeled_cost(r_cap + 1) <= m / 4:
        r_cap += 1
    for radius in range(1, r_cap + 1):
        tau_eff, bound = at_radius(radius)
        if bound <= farfield_rtol:
            return radius, tau_eff, bound
    radius = r_cap
    for r in range(1, r_cap + 1):
        if at_radius(r)[1] <= _FALLBACK_BOUND_CEIL:
            radius = r
            break
    tau_eff, bound = at_radius(radius)
    warnings.warn(
        f"farfield_rtol={farfield_rtol:g} is not provable by the quadtree "
        f"model within the profitable near-field budget (radius <= {r_cap} "
        f"of a {grid.gx}x{grid.gy} grid): the worst cell's dispersion gives "
        f"opening ratio {tau_eff:.3g} > required {tau_req:.3g}. Using radius "
        f"{radius} with worst-case bound {bound:.3g}; measured error is "
        "typically far below it — check farfield_error_report, or use a "
        "coarser grid / sub-cell-clustered data for a provable target.",
        UnprovableRtolWarning,
        stacklevel=4,
    )
    return radius, tau_eff, bound


def _quadtree_level_statics(qt, radius: int, tau_eff: float, cell_min: float,
                            side: int, tile_cap: int):
    """Static per-level ``(nx, ny, step, k_pad, tile)`` table.

    ``k_pad`` bounds how many CLOSED nodes one query block may emit at the
    level; the heuristic inverts the opening criterion with the level
    maxima: a level-``l`` node is closed only where its PARENT opened, and
    a parent at cell gap ``>= Gcap = max(radius+1, e_parent_max /
    (tau_eff*cell_min) + 1)`` never opens — so closed nodes live inside a
    bounded annulus of the block.  The top level has no parent (every node
    is a candidate).  Undersizing is safe: the engine detects per-block
    table overflow at execute time and routes those queries to the exact
    sweep, exactly like the near-capacity overflow blend.
    """
    n_lv = len(qt)
    out = []
    for lv, level in enumerate(qt):
        n_nodes = level.nx * level.ny
        if lv == n_lv - 1:
            k_est = n_nodes
        else:
            parent = qt[lv + 1]
            if tau_eff > 0 and cell_min > 0 and math.isfinite(tau_eff):
                gcap = max(radius + 1,
                           int(math.ceil(parent.e_max / (tau_eff * cell_min))) + 1)
            else:
                gcap = radius + 1
            span = (side + 2 * gcap) // parent.step + 2
            k_est = min(4 * span * span, n_nodes)
        k_est = max(k_est, 8)
        tile = min(tile_cap, max(128, _round_up(k_est, 128)))
        k_pad = _round_up(k_est, tile)
        out.append((level.nx, level.ny, level.step, k_pad, tile))
    return tuple(out)


def _choose_seam_level(grid: UniformGrid, window: int) -> int:
    """Morton seam-split depth from the occupancy histogram's window.

    Splitting at depth L bounds every query block's home-cell bbox to one
    ``4**L``-quadrant, so the seam-straddling rectangle blowup (a block with
    home cells on both sides of the grid's centre cross has a bbox near full
    grid width) cannot happen at any split boundary.  Deeper splits mean
    smaller worst-case rectangles but more block padding, so go only as deep
    as quadrants stay comfortably larger than the expected candidate window
    ``window`` (the same densest-window statistic that sizes the capacity):
    then a non-straddling block's rectangle was going to fit anyway and the
    split costs at most one padded block per occupied quadrant.
    """
    level = 0
    nbits = max(1, (max(grid.gx, grid.gy) - 1).bit_length())
    while (level < min(nbits, 4)
           and (min(grid.gx, grid.gy) >> (level + 1)) >= max(window, 4)):
        level += 1
    return level


def _plan_grid(dx, dy, dz, *, params, block_q, block_d, grid, target_occupancy,
               query_occupancy, seam_level, phase2, farfield_rtol,
               farfield_radius, min_cand_capacity=None, min_p2_capacity=None):
    """Grid-impl plan: snapshot + static capacity + block_d autotune.

    ``min_cand_capacity`` / ``min_p2_capacity`` floor the occupancy-model
    capacities (still clamped to ``m`` — a candidate row can never need
    more than every data point).  This is the capacity re-estimator's
    entry: a re-plan raises the floor past the observed ``cand_need_max``
    instead of re-deriving the same undersized model answer.
    """
    m = int(dx.shape[0])
    dtype = jnp.asarray(dx).dtype
    user_grid = grid is not None
    occupancy = target_occupancy or DEFAULT_OCCUPANCY
    if grid is None:
        grid = build_grid(dx, dy, dz, target_occupancy=occupancy)

    rebuilds = 0
    while True:
        r_need = required_radius_table(grid, params.k)
        capacity, r_static, window, side = _choose_candidate_capacity(
            grid, r_need, block_q, m, query_occupancy
        )
        pathological = grid.n_cells > 1 and r_static > _MAX_SAFE_RADIUS
        if not pathological:
            break
        if user_grid or rebuilds >= _MAX_REBUILDS:
            warnings.warn(
                f"grid resolution {grid.gx}x{grid.gy} is pathological for this "
                f"data (grid-max safe radius {r_static}, static candidate "
                f"window {window} cells); candidate rows approach a full "
                "sweep. Pass a coarser grid or higher target_occupancy.",
                PathologicalGridWarning,
                stacklevel=3,
            )
            break
        # coarsen: 4x the target occupancy halves the cells per axis,
        # raising occupancy in sparse regions and shrinking required_radius
        occupancy *= 4.0
        grid = build_grid(dx, dy, dz, target_occupancy=occupancy)
        rebuilds += 1

    # block_d autotune from the occupancy histogram: a candidate tile no
    # wider than the (128-aligned) capacity — narrow neighbourhoods get a
    # single tile instead of streaming block_d of sentinel padding
    capacity = min(capacity, m)
    if min_cand_capacity is not None:
        capacity = min(max(capacity, int(min_cand_capacity)), m)
    cand_block_d = min(block_d, max(128, _round_up(capacity, 128)))
    cand_capacity = _round_up(capacity, cand_block_d)

    if seam_level is None:
        seam_level = _choose_seam_level(grid, window)

    # Phase-2 full-data sweep: sentinel-pad to its own tile multiple (kept on
    # farfield plans too — it is the exact arm of the overflow fallback)
    bd2 = min(block_d, max(128, _round_up(m, 128)))
    big = coord_sentinel(dtype)
    data = (
        pad_to(jnp.asarray(dx), bd2, big)[None, :],
        pad_to(jnp.asarray(dy), bd2, big)[None, :],
        pad_to(jnp.asarray(dz), bd2, jnp.zeros((), dtype))[None, :],
    )

    ff = dict(farfield_radius=0, farfield_bound=0.0, p2_capacity=0,
              p2_block_d=0, p2_far_block_d=0, qt_tau=0.0, qt_levels=(),
              far=())
    if phase2 == "quadtree":
        qt = quadtree_aggregates(grid)
        cell_min = float(jnp.minimum(grid.cell_size[0], grid.cell_size[1]))
        a_max = float(max(params.alpha_levels))
        if farfield_radius is not None:  # user override: radius as given
            radius = max(1, min(int(farfield_radius), max(grid.gx, grid.gy)))
            tau_req = _quadtree_tau_required(a_max, farfield_rtol)
            if radius >= max(grid.gx, grid.gy):
                tau_eff, bound = tau_req, 0.0
            elif cell_min > 0:
                tau_eff = max(tau_req, qt[0].e_max / (radius * cell_min))
                bound = _bound_from_tau(tau_eff, a_max, dipole=True)
            else:
                tau_eff, bound = math.inf, math.inf
        else:
            radius, tau_eff, bound = _choose_quadtree_radius(
                grid, params, farfield_rtol, qt[0].e_max, side=side, m=m
            )
        # near-field machinery is shared with the single-level arm: same
        # densest-window capacity model, same tile autotune
        window2 = min(side + 2 * radius + 1, max(grid.gx, grid.gy))
        cap2 = min(_densest_window_count(grid, window2), m)
        if min_p2_capacity is not None:
            cap2 = min(max(cap2, int(min_p2_capacity)), m)
        tile_cap = max(512, _round_up(_P2_TILE_ELEMS // block_q, 128))
        p2_block_d = min(tile_cap, max(128, _round_up(cap2, 128)))
        p2_capacity = _round_up(cap2, p2_block_d)
        qt_levels = _quadtree_level_statics(qt, radius, tau_eff, cell_min,
                                            side, tile_cap)
        # per level: node aggregates + ONE appended sentinel node (index
        # nx*ny) that pad slots of the gathered per-block tables point to —
        # sentinel centroid (d2 -> inf, w -> 0) and zero count/z-sum/moment,
        # so pad slots contribute exactly 0 to both accumulators
        zero1 = jnp.zeros((1,), dtype)
        big1 = jnp.full((1,), big, dtype)
        far = tuple(
            (
                jnp.concatenate([level.cent_x.astype(dtype), big1]),
                jnp.concatenate([level.cent_y.astype(dtype), big1]),
                jnp.concatenate([level.count.astype(dtype), zero1]),
                jnp.concatenate([level.z_sum.astype(dtype), zero1]),
                jnp.concatenate([level.mx.astype(dtype), zero1]),
                jnp.concatenate([level.my.astype(dtype), zero1]),
                jnp.concatenate([level.e.astype(dtype), zero1]),
            )
            for level in qt
        )
        ff = dict(farfield_radius=radius, farfield_bound=float(bound),
                  p2_capacity=p2_capacity, p2_block_d=p2_block_d,
                  p2_far_block_d=0, qt_tau=float(tau_eff),
                  qt_levels=qt_levels, far=far)
    if phase2 == "farfield":
        agg = cell_aggregates(grid)
        if farfield_radius is not None:  # user override: radius as given
            radius = max(1, min(int(farfield_radius), max(grid.gx, grid.gy)))
            bound = _bound_at_radius(grid, params, agg, radius)
        else:
            radius, bound = _choose_farfield_radius(
                grid, params, farfield_rtol, agg, side=side, m=m
            )
        # near-field capacity: same densest-window model as Phase 1, with the
        # block's home bbox expanded by the near radius instead of r_safe
        window2 = min(side + 2 * radius + 1, max(grid.gx, grid.gy))
        cap2 = min(_densest_window_count(grid, window2), m)
        if min_p2_capacity is not None:
            cap2 = min(max(cap2, int(min_p2_capacity)), m)
        # Phase-2 tiles are autotuned independently of block_d: the near row
        # is narrow (<= capacity, vs m for the full sweep), so the widest
        # tile that keeps the (block_q x tile) distance/weight tile within a
        # ~1 MiB VMEM budget covers it in the fewest grid steps — per-step
        # overhead, not FLOPs, dominates both interpret mode and short grids
        tile_cap = max(512, _round_up(_P2_TILE_ELEMS // block_q, 128))
        p2_block_d = min(tile_cap, max(128, _round_up(cap2, 128)))
        p2_capacity = _round_up(cap2, p2_block_d)
        far_bd = min(tile_cap, _round_up(grid.n_cells, 128))
        zero = jnp.zeros((), dtype)
        far = (
            pad_to(agg.cent_x, far_bd, big)[None, :],
            pad_to(agg.cent_y, far_bd, big)[None, :],
            pad_to(agg.count, far_bd, zero)[None, :],
            pad_to(agg.z_sum, far_bd, zero)[None, :],
            pad_to(agg.ix, far_bd, jnp.asarray(-1, jnp.int32))[None, :],
            pad_to(agg.iy, far_bd, jnp.asarray(-1, jnp.int32))[None, :],
        )
        ff = dict(farfield_radius=radius, farfield_bound=float(bound),
                  p2_capacity=p2_capacity, p2_block_d=p2_block_d,
                  p2_far_block_d=far_bd, far=far)

    return dict(block_d=bd2, cand_capacity=cand_capacity, cand_block_d=cand_block_d,
                grid_rebuilds=rebuilds, seam_level=int(seam_level),
                data=data, grid=grid, r_need=r_need, **ff)


def build_plan(
    dx, dy, dz, *,
    params: AIDWParams = AIDWParams(),
    area: float | None = None,
    impl: Impl = "tiled",
    layout: Layout = "soa",
    block_q: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,
    grid: UniformGrid | None = None,
    knn: str = "brute",
    q_chunk: int = 1024,
    d_chunk: int = 4096,
    idw_alpha: float = 2.0,
    target_occupancy: float | None = None,
    query_occupancy: float | None = None,
    seam_level: int | None = None,
    pipeline: str = "prefetch",
    phase2: str = "exact",
    farfield_rtol: float = 1e-3,
    farfield_radius: int | None = None,
    min_cand_capacity: int | None = None,
    min_p2_capacity: int | None = None,
) -> InterpolationPlan:
    """Build an :class:`InterpolationPlan` from a dataset + configuration.

    The one place padding/sentinel/layout decisions are made for every impl
    (the kernels' public wrappers in ``kernels.ops``, the pure-jnp
    ``aidw_interpolate`` and the distributed sharded path all plan here).

    ``impl``: the dense kernel family ("naive", "tiled", "binned", "fused",
    "tiled_v2"), the static-shape grid path ("grid"), the pure-jnp chunked
    path ("chunked", with ``knn`` = "brute" | "grid"), or constant-power
    "idw".  ``grid=`` supplies a prebuilt :class:`UniformGrid` (reused, never
    rebuilt); ``target_occupancy`` seeds the auto-resolution otherwise.
    ``query_occupancy`` (grid impl) sizes the static candidate capacity: the
    expected queries per cell of a serving batch (default: data occupancy /
    4).  Lower values buy headroom for sparse batches at the cost of wider
    candidate rows; queries in blocks beyond the capacity stay exact via the
    per-block ring-search blend.
    ``seam_level`` (grid impl) is the Morton quadrant depth at which query
    blocks are split during the execute-side sort so no block straddles a
    top-level Z-order seam (the rectangle-blowup worst case); ``None``
    auto-chooses from the occupancy histogram, ``0`` disables.
    ``pipeline`` (grid impl) selects the Phase-1 kernel: "prefetch" (default;
    scalar-prefetch indexed tile table — sparse blocks skip their
    all-sentinel candidate tiles) or "dense" (every block walks the full
    static capacity; the conservative fallback, bit-identical results).
    ``phase2`` (grid impl) selects the Phase-2 sweep: "exact" (default; the
    full m-point weighted sweep, bit-identical to every prior release),
    "farfield" (exact per-point weights only inside a plan-chosen near-field
    radius, one aggregate term per far cell beyond it — the first
    *approximating* path; its worst-case relative error, proved by the
    model in :func:`_choose_farfield_radius` and enforced by
    ``tests/engine/test_farfield.py``, is reported as
    ``plan.farfield_bound``.  The bound meets ``farfield_rtol`` when that
    is provable at a profitable radius; otherwise the plan WARNS and
    ``farfield_bound`` is the honest, larger worst case — always check it
    rather than assuming the request was met), or "quadtree" (DESIGN.md §8:
    the far field is walked as a Barnes–Hut quadtree of cell aggregates,
    coarse levels closed wherever the per-node opening criterion holds and
    a dipole z-moment term added per closed node, making BOTH error terms
    second-order in the opening ratio — per-query far work drops to
    ~O(log m) and rtol=1e-3 becomes provable wherever data clusters below
    the cell scale; same near-field machinery, same ``farfield_bound``
    reporting contract as "farfield").
    ``farfield_rtol`` is the requested relative-error ceiling, measured
    against ``max|z_data|`` (see ``core.accuracy.farfield_error_report``);
    when it is not provable at a profitable radius the plan warns and
    reports the honest (larger) bound.  ``farfield_radius`` overrides the
    model's radius choice directly (the bound is still computed and
    reported for the chosen radius — possibly ``inf`` for radii too small
    to prove anything).
    ``min_cand_capacity`` / ``min_p2_capacity`` (grid impl) floor the
    occupancy-model capacities, clamped to ``m`` — the capacity
    re-estimator's re-plan knob (see :func:`replan_with_capacity`).

    Data must be finite: non-finite coordinates or z values raise
    ``ValueError`` (a NaN data point would silently poison every distance
    reduction it streams through).  Non-finite *queries* are handled at
    execute time instead — they yield NaN results.
    """
    valid_impls = _DENSE_IMPLS + ("grid", "idw", "chunked")
    if impl not in valid_impls:
        raise ValueError(f"impl must be one of {valid_impls}, got {impl!r}")
    if layout not in ("soa", "aoas"):
        raise ValueError(layout)
    if layout == "aoas" and impl in _SOA_ONLY:
        raise ValueError(f"impl={impl!r} is SoA-only (not available for layout=aoas)")
    uses_grid = impl == "grid" or (impl == "chunked" and knn == "grid")
    if grid is not None and not uses_grid:
        raise ValueError("grid= is only meaningful with impl='grid' or knn='grid'")
    if impl == "chunked" and knn not in ("brute", "grid"):
        raise ValueError(f"knn must be 'brute' or 'grid', got {knn!r}")
    if pipeline not in ("prefetch", "dense"):
        raise ValueError(f"pipeline must be 'prefetch' or 'dense', got {pipeline!r}")
    if seam_level is not None and not (0 <= int(seam_level) <= 8):
        raise ValueError(f"seam_level must be in [0, 8], got {seam_level!r}")
    if phase2 not in ("exact", "farfield", "quadtree"):
        raise ValueError(f"phase2 must be 'exact', 'farfield' or 'quadtree', "
                         f"got {phase2!r}")
    if phase2 in ("farfield", "quadtree") and impl != "grid":
        raise ValueError(f"phase2={phase2!r} requires impl='grid' (the cell "
                         "aggregates live on the grid snapshot)")
    if not float(farfield_rtol) > 0.0:
        raise ValueError(f"farfield_rtol must be > 0, got {farfield_rtol!r}")
    if farfield_radius is not None and int(farfield_radius) < 1:
        raise ValueError(f"farfield_radius must be >= 1, got {farfield_radius!r}")
    for name, floor in (("min_cand_capacity", min_cand_capacity),
                        ("min_p2_capacity", min_p2_capacity)):
        if floor is not None and int(floor) < 1:
            raise ValueError(f"{name} must be >= 1, got {floor!r}")

    # Reject non-finite data eagerly (tracers — the sharded chunked path
    # plans inside shard_map — can't be checked and are trusted instead).
    for name, arr in (("dx", dx), ("dy", dy), ("dz", dz)):
        if isinstance(arr, jax.core.Tracer):
            continue
        vals = jnp.asarray(arr)
        if jnp.issubdtype(vals.dtype, jnp.floating) and not bool(
            jnp.all(jnp.isfinite(vals))
        ):
            raise ValueError(
                f"non-finite values in {name}: data points and z must be "
                "finite (NaN/Inf would silently poison the kernel distance "
                "reductions). Filter the dataset before planning."
            )

    m = int(dx.shape[0])
    if impl != "idw" and m < params.k:
        raise ValueError(f"need at least k={params.k} data points, got {m}")
    if area is None:
        area = params.area
    if area is None:
        if impl != "idw":  # constant-power IDW has no Eq. (2), no area
            raise ValueError("plans require a static area; pass area= or set params.area")
        area = 0.0
    area = float(area)
    params = dataclasses.replace(params, alpha_levels=tuple(params.alpha_levels))
    interp = _auto_interpret(interpret)
    dtype = jnp.asarray(dx).dtype

    fields = dict(
        impl=impl, layout=layout, params=params, area=area, m=m,
        block_q=block_q, block_d=block_d, interpret=interp,
        knn=knn, q_chunk=q_chunk, d_chunk=d_chunk, idw_alpha=float(idw_alpha),
        cand_capacity=0, cand_block_d=0, grid_rebuilds=0,
        seam_level=0, pipeline=pipeline,
        phase2=phase2, farfield_rtol=float(farfield_rtol),
        farfield_radius=0, farfield_bound=0.0,
        p2_capacity=0, p2_block_d=0, p2_far_block_d=0,
        qt_tau=0.0, qt_levels=(),
        data=(), grid=None, r_need=None, far=(),
    )

    if impl == "grid":
        fields.update(_plan_grid(
            dx, dy, dz, params=params, block_q=block_q, block_d=block_d,
            grid=grid, target_occupancy=target_occupancy,
            query_occupancy=query_occupancy, seam_level=seam_level,
            phase2=phase2, farfield_rtol=float(farfield_rtol),
            farfield_radius=farfield_radius,
            min_cand_capacity=min_cand_capacity,
            min_p2_capacity=min_p2_capacity,
        ))
    elif impl == "chunked":
        if knn == "grid" and grid is None:
            grid = build_grid(dx, dy, dz, target_occupancy=target_occupancy or DEFAULT_OCCUPANCY)
        fields.update(data=(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz)), grid=grid)
    else:
        # dense kernel family + idw: sentinel-pad the streamed data axis
        if impl == "naive":
            fields["block_q"] = min(block_q, 64)
        big = coord_sentinel(dtype)
        dxp = pad_to(jnp.asarray(dx), block_d, big)
        dyp = pad_to(jnp.asarray(dy), block_d, big)
        dzp = pad_to(jnp.asarray(dz), block_d, jnp.zeros((), dtype))
        if layout == "aoas":
            fields.update(data=(soa_to_aoas(dxp, dyp, dzp),))
        else:
            fields.update(data=(dxp[None, :], dyp[None, :], dzp[None, :]))

    return InterpolationPlan(**fields)


def replan_with_capacity(
    plan: InterpolationPlan, *,
    min_cand_capacity: int | None = None,
    min_p2_capacity: int | None = None,
) -> InterpolationPlan:
    """Rebuild a grid plan with floored capacities — the re-plan entry the
    serving-layer capacity re-estimator calls from its background thread.

    Everything else is carried over from ``plan``: the original (unpadded)
    data arrays are recovered from the plan's padded copies, the grid
    snapshot is REUSED (no rebuild — the data didn't change, the capacity
    model did), and the statics (params/area/blocks/seam/pipeline/phase2
    and the far-field knobs, including an explicit-radius carry-over so the
    radius cannot drift between old and new plan) are passed through.  The
    result serves the same queries with the same exactness contract; only
    the static candidate widths (and their derived tile sizes) grow.
    """
    if plan.impl != "grid":
        raise ValueError(
            f"replan_with_capacity requires impl='grid', got {plan.impl!r}"
        )
    dxp, dyp, dzp = plan.data
    dx, dy, dz = dxp[0, :plan.m], dyp[0, :plan.m], dzp[0, :plan.m]
    return build_plan(
        dx, dy, dz,
        params=plan.params, area=plan.area, impl="grid",
        block_q=plan.block_q, block_d=plan.block_d,
        interpret=plan.interpret, grid=plan.grid,
        seam_level=plan.seam_level, pipeline=plan.pipeline,
        phase2=plan.phase2, farfield_rtol=plan.farfield_rtol,
        farfield_radius=plan.farfield_radius or None,
        min_cand_capacity=min_cand_capacity,
        min_p2_capacity=min_p2_capacity,
    )
