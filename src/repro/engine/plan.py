"""Plan construction — the eager half of the plan/execute engine.

A plan captures, once per dataset, every decision that would otherwise leak
data-dependent *shapes* into the hot path:

* padded data layouts (sentinel coordinates, block-multiple widths, the
  SoA/AoaS transform) for the dense kernel family;
* the grid impl's **static-shape snapshot**: the :class:`UniformGrid` (with
  its CSR point arrays), the per-cell ``required_radius`` table, and a fixed
  candidate capacity chosen from the occupancy histogram — including the
  per-workload ``block_d`` autotune and the pathological-resolution
  warn-or-rebuild loop (ROADMAP item);
* chunk sizes / constant powers for the pure-jnp and IDW paths.

Everything a plan stores is either a static (hashable aux data of the
pytree, a trace-time constant) or an array child, so ``execute(plan, ...)``
jits with the plan as an ordinary argument and two same-shape query batches
against one plan hit the same executable.  Plan construction is eager by
design for ``impl="grid"`` (capacities are concrete ints); the ``chunked``
brute path builds traceable plans so the distributed sharded path can plan
inside ``shard_map``.
"""

from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.aidw import AIDWParams
from repro.core.grid import (
    DEFAULT_OCCUPANCY,
    UniformGrid,
    build_grid,
    required_radius_table,
    static_cell_radius,
)
from repro.core.layouts import coord_sentinel, pad_to, soa_to_aoas

Impl = Literal["naive", "tiled", "binned", "fused", "grid", "tiled_v2", "idw", "chunked"]
Layout = Literal["soa", "aoas"]

_DENSE_IMPLS = ("naive", "tiled", "binned", "fused", "tiled_v2")
_SOA_ONLY = ("binned", "fused", "grid", "tiled_v2", "idw", "chunked")

# Rebuild threshold: a resolution is "pathological" when some cell needs a
# safe ring radius beyond this — the signature of a grid too fine for its
# data (clustered points leave most cells empty, so ``required_radius``
# explodes in the voids and candidate rectangles approach a full sweep).
# A well-sized grid sits at r_safe ~ 2-3 (see ``static_cell_radius``).
_MAX_SAFE_RADIUS = 6
_MAX_REBUILDS = 3


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return bool(interpret)
    return jax.default_backend() != "tpu"


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class InterpolationPlan:
    """Everything needed to interpolate any number of query batches.

    Static fields (pytree aux — trace-time constants, part of the jit cache
    key) vs array children (``data``, ``grid``, ``r_need``) are split so the
    whole plan passes through ``jax.jit`` as one argument.
    """

    # --- static ---
    impl: str
    layout: str
    params: AIDWParams
    area: float
    m: int                    # real (unpadded) data-point count
    block_q: int
    block_d: int              # data-axis tile: dense sweep / grid Phase 2
    interpret: bool
    knn: str                  # chunked: "brute" | "grid"
    q_chunk: int
    d_chunk: int
    idw_alpha: float
    cand_capacity: int        # grid: static candidate-row width (points)
    cand_block_d: int         # grid: Phase-1 candidate tile (autotuned)
    grid_rebuilds: int        # grid: coarsening rebuilds during planning
    seam_level: int           # grid: Morton quadrant split depth (0 = off)
    pipeline: str             # grid Phase 1: "prefetch" (tile-skip) | "dense"
    # --- children ---
    data: tuple               # impl-specific padded arrays
    grid: UniformGrid | None
    r_need: jnp.ndarray | None  # (gy, gx) int32 per-cell required_radius

    def tree_flatten(self):
        aux = (self.impl, self.layout, self.params, self.area, self.m,
               self.block_q, self.block_d, self.interpret, self.knn,
               self.q_chunk, self.d_chunk, self.idw_alpha,
               self.cand_capacity, self.cand_block_d, self.grid_rebuilds,
               self.seam_level, self.pipeline)
        return (self.data, self.grid, self.r_need), aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        data, grid, r_need = children
        return cls(*aux, data=data, grid=grid, r_need=r_need)


def _choose_candidate_capacity(grid: UniformGrid, r_need, block_q: int, m: int,
                               query_occupancy: float | None):
    """Static candidate capacity (points) from the occupancy histogram.

    A block of ``block_q`` Morton-contiguous queries at ~``query_occupancy``
    queries per cell spans a home-cell bbox of side about
    ``2*ceil(sqrt(block_q / query_occupancy))`` (a contiguous Morton run of
    L cells fits a box of side <= 2*ceil(sqrt(L))); expanding by the
    grid-max in-cell safe radius bounds the rectangle side ``W``.  The
    capacity is the densest WxW occupancy window (one integral-image sweep).
    Query density is unknowable at plan time, so the default assumes serving
    batches ~4x sparser than the data; blocks that exceed the capacity at
    execute time (sparser/far-out-of-bbox batches) take the exact
    ring-search fallback instead of losing neighbours.

    Returns ``(capacity, r_static, window)`` — all concrete ints.
    """
    r_cell = static_cell_radius(grid, r_need)
    r_static = int(jnp.max(r_cell))
    occ_mean = max(m / max(grid.n_cells, 1), 1.0)
    if query_occupancy is None:
        query_occupancy = occ_mean / 4.0
    query_occupancy = max(query_occupancy, 0.5)
    side = 2 * math.ceil(math.sqrt(block_q / query_occupancy))
    window = min(side + 2 * r_static + 1, max(grid.gx, grid.gy))
    c = grid.cum
    ys = jnp.minimum(jnp.arange(grid.gy, dtype=jnp.int32) + window, grid.gy)
    xs = jnp.minimum(jnp.arange(grid.gx, dtype=jnp.int32) + window, grid.gx)
    y0 = jnp.arange(grid.gy, dtype=jnp.int32)
    x0 = jnp.arange(grid.gx, dtype=jnp.int32)
    sums = (c[ys[:, None], xs[None, :]] - c[y0[:, None], xs[None, :]]
            - c[ys[:, None], x0[None, :]] + c[y0[:, None], x0[None, :]])
    capacity = int(jnp.max(sums))
    return max(capacity, 1), r_static, window


def _choose_seam_level(grid: UniformGrid, window: int) -> int:
    """Morton seam-split depth from the occupancy histogram's window.

    Splitting at depth L bounds every query block's home-cell bbox to one
    ``4**L``-quadrant, so the seam-straddling rectangle blowup (a block with
    home cells on both sides of the grid's centre cross has a bbox near full
    grid width) cannot happen at any split boundary.  Deeper splits mean
    smaller worst-case rectangles but more block padding, so go only as deep
    as quadrants stay comfortably larger than the expected candidate window
    ``window`` (the same densest-window statistic that sizes the capacity):
    then a non-straddling block's rectangle was going to fit anyway and the
    split costs at most one padded block per occupied quadrant.
    """
    level = 0
    nbits = max(1, (max(grid.gx, grid.gy) - 1).bit_length())
    while (level < min(nbits, 4)
           and (min(grid.gx, grid.gy) >> (level + 1)) >= max(window, 4)):
        level += 1
    return level


def _plan_grid(dx, dy, dz, *, params, block_q, block_d, grid, target_occupancy,
               query_occupancy, seam_level):
    """Grid-impl plan: snapshot + static capacity + block_d autotune."""
    m = int(dx.shape[0])
    dtype = jnp.asarray(dx).dtype
    user_grid = grid is not None
    occupancy = target_occupancy or DEFAULT_OCCUPANCY
    if grid is None:
        grid = build_grid(dx, dy, dz, target_occupancy=occupancy)

    rebuilds = 0
    while True:
        r_need = required_radius_table(grid, params.k)
        capacity, r_static, window = _choose_candidate_capacity(
            grid, r_need, block_q, m, query_occupancy
        )
        pathological = grid.n_cells > 1 and r_static > _MAX_SAFE_RADIUS
        if not pathological:
            break
        if user_grid or rebuilds >= _MAX_REBUILDS:
            warnings.warn(
                f"grid resolution {grid.gx}x{grid.gy} is pathological for this "
                f"data (grid-max safe radius {r_static}, static candidate "
                f"window {window} cells); candidate rows approach a full "
                "sweep. Pass a coarser grid or higher target_occupancy.",
                stacklevel=3,
            )
            break
        # coarsen: 4x the target occupancy halves the cells per axis,
        # raising occupancy in sparse regions and shrinking required_radius
        occupancy *= 4.0
        grid = build_grid(dx, dy, dz, target_occupancy=occupancy)
        rebuilds += 1

    # block_d autotune from the occupancy histogram: a candidate tile no
    # wider than the (128-aligned) capacity — narrow neighbourhoods get a
    # single tile instead of streaming block_d of sentinel padding
    capacity = min(capacity, m)
    cand_block_d = min(block_d, max(128, _round_up(capacity, 128)))
    cand_capacity = _round_up(capacity, cand_block_d)

    if seam_level is None:
        seam_level = _choose_seam_level(grid, window)

    # Phase-2 full-data sweep: sentinel-pad to its own tile multiple
    bd2 = min(block_d, max(128, _round_up(m, 128)))
    big = coord_sentinel(dtype)
    data = (
        pad_to(jnp.asarray(dx), bd2, big)[None, :],
        pad_to(jnp.asarray(dy), bd2, big)[None, :],
        pad_to(jnp.asarray(dz), bd2, jnp.zeros((), dtype))[None, :],
    )
    return dict(block_d=bd2, cand_capacity=cand_capacity, cand_block_d=cand_block_d,
                grid_rebuilds=rebuilds, seam_level=int(seam_level),
                data=data, grid=grid, r_need=r_need)


def build_plan(
    dx, dy, dz, *,
    params: AIDWParams = AIDWParams(),
    area: float | None = None,
    impl: Impl = "tiled",
    layout: Layout = "soa",
    block_q: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,
    grid: UniformGrid | None = None,
    knn: str = "brute",
    q_chunk: int = 1024,
    d_chunk: int = 4096,
    idw_alpha: float = 2.0,
    target_occupancy: float | None = None,
    query_occupancy: float | None = None,
    seam_level: int | None = None,
    pipeline: str = "prefetch",
) -> InterpolationPlan:
    """Build an :class:`InterpolationPlan` from a dataset + configuration.

    The one place padding/sentinel/layout decisions are made for every impl
    (the kernels' public wrappers in ``kernels.ops``, the pure-jnp
    ``aidw_interpolate`` and the distributed sharded path all plan here).

    ``impl``: the dense kernel family ("naive", "tiled", "binned", "fused",
    "tiled_v2"), the static-shape grid path ("grid"), the pure-jnp chunked
    path ("chunked", with ``knn`` = "brute" | "grid"), or constant-power
    "idw".  ``grid=`` supplies a prebuilt :class:`UniformGrid` (reused, never
    rebuilt); ``target_occupancy`` seeds the auto-resolution otherwise.
    ``query_occupancy`` (grid impl) sizes the static candidate capacity: the
    expected queries per cell of a serving batch (default: data occupancy /
    4).  Lower values buy headroom for sparse batches at the cost of wider
    candidate rows; queries in blocks beyond the capacity stay exact via the
    per-block ring-search blend.
    ``seam_level`` (grid impl) is the Morton quadrant depth at which query
    blocks are split during the execute-side sort so no block straddles a
    top-level Z-order seam (the rectangle-blowup worst case); ``None``
    auto-chooses from the occupancy histogram, ``0`` disables.
    ``pipeline`` (grid impl) selects the Phase-1 kernel: "prefetch" (default;
    scalar-prefetch indexed tile table — sparse blocks skip their
    all-sentinel candidate tiles) or "dense" (every block walks the full
    static capacity; the conservative fallback, bit-identical results).
    """
    valid_impls = _DENSE_IMPLS + ("grid", "idw", "chunked")
    if impl not in valid_impls:
        raise ValueError(f"impl must be one of {valid_impls}, got {impl!r}")
    if layout not in ("soa", "aoas"):
        raise ValueError(layout)
    if layout == "aoas" and impl in _SOA_ONLY:
        raise ValueError(f"impl={impl!r} is SoA-only (not available for layout=aoas)")
    uses_grid = impl == "grid" or (impl == "chunked" and knn == "grid")
    if grid is not None and not uses_grid:
        raise ValueError("grid= is only meaningful with impl='grid' or knn='grid'")
    if impl == "chunked" and knn not in ("brute", "grid"):
        raise ValueError(f"knn must be 'brute' or 'grid', got {knn!r}")
    if pipeline not in ("prefetch", "dense"):
        raise ValueError(f"pipeline must be 'prefetch' or 'dense', got {pipeline!r}")
    if seam_level is not None and not (0 <= int(seam_level) <= 8):
        raise ValueError(f"seam_level must be in [0, 8], got {seam_level!r}")

    m = int(dx.shape[0])
    if impl != "idw" and m < params.k:
        raise ValueError(f"need at least k={params.k} data points, got {m}")
    if area is None:
        area = params.area
    if area is None:
        if impl != "idw":  # constant-power IDW has no Eq. (2), no area
            raise ValueError("plans require a static area; pass area= or set params.area")
        area = 0.0
    area = float(area)
    params = dataclasses.replace(params, alpha_levels=tuple(params.alpha_levels))
    interp = _auto_interpret(interpret)
    dtype = jnp.asarray(dx).dtype

    fields = dict(
        impl=impl, layout=layout, params=params, area=area, m=m,
        block_q=block_q, block_d=block_d, interpret=interp,
        knn=knn, q_chunk=q_chunk, d_chunk=d_chunk, idw_alpha=float(idw_alpha),
        cand_capacity=0, cand_block_d=0, grid_rebuilds=0,
        seam_level=0, pipeline=pipeline,
        data=(), grid=None, r_need=None,
    )

    if impl == "grid":
        fields.update(_plan_grid(
            dx, dy, dz, params=params, block_q=block_q, block_d=block_d,
            grid=grid, target_occupancy=target_occupancy,
            query_occupancy=query_occupancy, seam_level=seam_level,
        ))
    elif impl == "chunked":
        if knn == "grid" and grid is None:
            grid = build_grid(dx, dy, dz, target_occupancy=target_occupancy or DEFAULT_OCCUPANCY)
        fields.update(data=(jnp.asarray(dx), jnp.asarray(dy), jnp.asarray(dz)), grid=grid)
    else:
        # dense kernel family + idw: sentinel-pad the streamed data axis
        if impl == "naive":
            fields["block_q"] = min(block_q, 64)
        big = coord_sentinel(dtype)
        dxp = pad_to(jnp.asarray(dx), block_d, big)
        dyp = pad_to(jnp.asarray(dy), block_d, big)
        dzp = pad_to(jnp.asarray(dz), block_d, jnp.zeros((), dtype))
        if layout == "aoas":
            fields.update(data=(soa_to_aoas(dxp, dyp, dzp),))
        else:
            fields.update(data=(dxp[None, :], dyp[None, :], dzp[None, :]))

    return InterpolationPlan(**fields)
