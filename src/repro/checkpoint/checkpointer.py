"""Fault-tolerant checkpointing.

Design points for the 1000-node posture:
* atomic publish — write to ``step_XXXX.tmp`` then ``os.replace`` so a crash
  mid-save never corrupts the latest checkpoint;
* manifest with integrity hashes; restore verifies before trusting;
* **mesh-elastic restore** — arrays are stored logically (gathered); restore
  accepts a tree of NamedShardings and ``jax.device_put``s onto the *current*
  mesh, so a job checkpointed on 512 chips restarts on 256 (tested);
* keep-last-N garbage collection;
* ``save_on_signal`` — emergency checkpoint hook (SIGTERM preemption).

(At real scale the gather becomes per-shard files keyed by shard index — the
manifest format already carries shapes/dtypes per leaf so that change is
local to ``_write``/``_read``.)
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import signal
import shutil

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ----------------------------------------------------------------- save
    def save(self, step: int, tree) -> str:
        flat = _flatten_with_paths(tree)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
        final = os.path.join(self.dir, f"step_{step:08d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {"step": step, "leaves": {}}
        for k, a in arrays.items():
            fname = hashlib.sha1(k.encode()).hexdigest()[:16] + ".npy"
            np.save(os.path.join(tmp, fname), a)
            manifest["leaves"][k] = {
                "file": fname,
                "shape": list(a.shape),
                "dtype": str(a.dtype),
                "sha1": hashlib.sha1(a.tobytes()).hexdigest(),
            }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # -------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self):
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, target, step: int | None = None, shardings=None, verify: bool = True):
        """Restore into the structure of ``target`` (arrays or
        ShapeDtypeStructs).  ``shardings``: optional matching tree of
        NamedShardings — this is the elastic-resharding path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        root = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(root, "manifest.json")) as f:
            manifest = json.load(f)
        paths = _flatten_with_paths(target)
        leaves, treedef = jax.tree_util.tree_flatten(target)
        flat_shardings = (
            treedef.flatten_up_to(shardings) if shardings is not None else [None] * len(leaves)
        )
        restored = []
        for (key, tgt), shard in zip(paths.items(), flat_shardings):
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint {step} missing leaf {key}")
            a = np.load(os.path.join(root, meta["file"]))
            if verify and hashlib.sha1(a.tobytes()).hexdigest() != meta["sha1"]:
                raise IOError(f"checkpoint corruption in leaf {key}")
            if tuple(a.shape) != tuple(tgt.shape):
                raise ValueError(f"shape mismatch for {key}: ckpt {a.shape} vs target {tgt.shape}")
            restored.append(jax.device_put(a, shard) if shard is not None else jax.numpy.asarray(a))
        return treedef.unflatten(restored), step

    # ------------------------------------------------------------ emergency
    def save_on_signal(self, get_state, signum=signal.SIGTERM):
        """Install an emergency-save handler (preemption notice)."""

        def handler(sig, frame):
            step, tree = get_state()
            self.save(step, tree)
            raise SystemExit(143)

        signal.signal(signum, handler)
