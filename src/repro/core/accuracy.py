"""Accuracy tooling — beyond-paper f32 error control (EXPERIMENTS §Accuracy).

The paper's answer to f32 rounding is "use f64", which costs 1/24 rate on its
GPU and has NO native support on TPU.  The dominant f32 error source in AIDW
is the long accumulation chain of Σw and Σw·z over m data points (w spans
many orders of magnitude near the query).  Kahan-compensated accumulation of
the cross-tile partials recovers ~f64 accuracy at f32 cost — the TPU-native
replacement for the paper's double-precision variant.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.aidw import AIDWParams, adaptive_alpha, _sq_dists
from repro.core.knn import running_k_best


def kahan_add(s, c, x):
    """One compensated accumulation step: returns (new_sum, new_compensation)."""
    y = x - c
    t = s + y
    c_new = (t - s) - y
    return t, c_new


@partial(jax.jit, static_argnames=("params", "area", "q_chunk", "d_chunk"))
def aidw_interpolate_kahan(
    dx, dy, dz, qx, qy,
    params: AIDWParams = AIDWParams(),
    *,
    area: float,
    q_chunk: int = 1024,
    d_chunk: int = 4096,
):
    """Tiled AIDW with Kahan-compensated cross-tile Σw / Σw·z accumulators.

    Same structure as :func:`repro.core.aidw.aidw_interpolate`; only the
    weight-pass carry differs.  Returns ``(z_hat, alpha)``.
    """
    m, n = dx.shape[0], qx.shape[0]
    dtype = qx.dtype
    big = jnp.asarray(jnp.finfo(dtype).max / 4, dtype)
    m_pad = (-m) % d_chunk
    dxp = jnp.concatenate([dx, jnp.full((m_pad,), big, dtype)])
    dyp = jnp.concatenate([dy, jnp.full((m_pad,), big, dtype)])
    dzp = jnp.concatenate([dz, jnp.zeros((m_pad,), dtype)])
    n_pad = (-n) % q_chunk
    qxp = jnp.concatenate([qx, jnp.zeros((n_pad,), dtype)])
    qyp = jnp.concatenate([qy, jnp.zeros((n_pad,), dtype)])
    tiles = (dxp.reshape(-1, d_chunk), dyp.reshape(-1, d_chunk), dzp.reshape(-1, d_chunk))

    def per_q(q):
        qcx, qcy = q

        def knn_step(best, tile):
            tx, ty, _ = tile
            return running_k_best(best, _sq_dists(qcx, qcy, tx, ty)), None

        best0 = jnp.full((q_chunk, params.k), jnp.inf, dtype)
        best, _ = jax.lax.scan(knn_step, best0, tiles)
        alpha = adaptive_alpha(jnp.mean(jnp.sqrt(best), axis=1), m, area, params)
        ah = alpha * 0.5

        def w_step(carry, tile):
            sw, cw, swz, cwz, min_d2, hit_z = carry
            tx, ty, tz = tile
            d2 = _sq_dists(qcx, qcy, tx, ty)
            tiny = jnp.asarray(1e-30 if dtype == jnp.float32 else 1e-290, dtype)
            w = jnp.exp(-ah[:, None] * jnp.log(jnp.maximum(d2, tiny)))
            sw, cw = kahan_add(sw, cw, jnp.sum(w, axis=1))
            swz, cwz = kahan_add(swz, cwz, jnp.sum(w * tz[None, :], axis=1))
            tmin = jnp.min(d2, axis=1)
            thz = tz[jnp.argmin(d2, axis=1)]
            better = tmin < min_d2
            return (sw, cw, swz, cwz, jnp.where(better, tmin, min_d2), jnp.where(better, thz, hit_z)), None

        zeros = jnp.zeros((q_chunk,), dtype)
        carry0 = (zeros, zeros, zeros, zeros, jnp.full((q_chunk,), jnp.inf, dtype), zeros)
        (sw, _, swz, _, min_d2, hit_z), _ = jax.lax.scan(w_step, carry0, tiles)
        zhat = jnp.where(min_d2 <= params.exact_hit_eps, hit_z, swz / sw)
        return zhat, alpha

    zhat, alpha = jax.lax.map(per_q, (qxp.reshape(-1, q_chunk), qyp.reshape(-1, q_chunk)))
    return zhat.reshape(-1)[:n], alpha.reshape(-1)[:n]


def relative_rmse(approx, exact):
    """RMS of (approx-exact) normalised by RMS(exact) — the §Accuracy metric."""
    approx = jnp.asarray(approx, jnp.float64) if approx.dtype != jnp.float64 else approx
    e = jnp.asarray(exact, approx.dtype)
    return float(jnp.sqrt(jnp.mean((approx - e) ** 2)) / jnp.sqrt(jnp.mean(e**2)))


# Floating-point slack separating the far-field *model* bound (exact
# arithmetic) from a measured comparison of two finite-precision pipelines:
# both the approximated path and the Kahan oracle round, so a mathematically
# 0-error case (one point per far cell — the aggregate IS the point; or a
# phase2="exact" plan, bound 0.0) still measures O(eps).  The slack scales
# with sqrt(m) because the compared path accumulates plain-dtype partial
# sums over m terms (random-rounding growth); at 64 ulps * sqrt(m) it stays
# orders of magnitude below any useful farfield_rtol (2.4e-3 at f32/m=100K)
# while covering the measured drift of the exact impls vs the Kahan oracle
# (the golden gate observes ~1e-4 relative at m=900).
FP_SLACK_ULPS = 64


def farfield_error_report(plan, qx, qy, *, q_chunk: int = 1024, d_chunk: int = 4096):
    """Measure a plan's Phase-2 approximation error against the Kahan oracle.

    The verification half of the far-field contract (the other half is the
    plan-time model in ``engine.plan._choose_farfield_radius``): runs
    ``execute(plan, qx, qy)``, recomputes the exact interpolant with the
    Kahan-compensated oracle (:func:`aidw_interpolate_kahan` — ~f64-quality
    accumulation at the data dtype), and reports the measured relative
    error on the same scale the bound is stated on, ``max|z_data|``.

    Returns a dict: ``max_rel_err`` / ``rms_rel_err`` / ``max_abs_err``
    (diffs in f64), ``scale``, ``phase2`` (which Phase-2 arm the plan runs
    — the report covers all three: "exact" plans measure pure fp drift
    against bound 0.0, "farfield" the single-level aggregate bound, and
    "quadtree" the multi-level dipole bound of DESIGN.md §8), ``bound``
    (the plan's ``farfield_bound``), ``fp_slack`` (see
    :data:`FP_SLACK_ULPS`), and ``within_bound`` — ``max_rel_err <= bound
    + fp_slack``, the predicate the error-budget tests
    (``tests/engine/test_farfield.py``, ``tests/engine/test_quadtree.py``)
    enforce.
    """
    import numpy as np

    from repro.engine import execute  # lazy: core <-> engine

    if plan.impl != "grid":
        raise ValueError("farfield_error_report expects an impl='grid' plan "
                         f"(got impl={plan.impl!r})")
    dxp, dyp, dzp = plan.data
    dx, dy, dz = dxp[0, :plan.m], dyp[0, :plan.m], dzp[0, :plan.m]
    z_approx, _ = execute(plan, qx, qy)
    z_exact, _ = aidw_interpolate_kahan(
        dx, dy, dz, qx, qy, plan.params,
        area=plan.area, q_chunk=q_chunk, d_chunk=d_chunk,
    )
    za = np.asarray(z_approx, np.float64)
    ze = np.asarray(z_exact, np.float64)
    scale = max(float(np.max(np.abs(np.asarray(dz, np.float64)))), 1e-300)
    diff = np.abs(za - ze)
    bound = float(plan.farfield_bound)
    fp_slack = (FP_SLACK_ULPS * float(jnp.finfo(dx.dtype).eps)
                * max(1.0, float(np.sqrt(plan.m))))
    max_rel = float(diff.max() / scale) if diff.size else 0.0
    return {
        "max_rel_err": max_rel,
        "rms_rel_err": float(np.sqrt(np.mean(diff**2)) / scale) if diff.size else 0.0,
        "max_abs_err": float(diff.max()) if diff.size else 0.0,
        "scale": scale,
        "phase2": plan.phase2,
        "bound": bound,
        "fp_slack": fp_slack,
        "within_bound": max_rel <= bound + fp_slack,
        "n_queries": int(np.asarray(qx).shape[0]),
    }
