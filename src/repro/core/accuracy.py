"""Accuracy tooling — beyond-paper f32 error control (EXPERIMENTS §Accuracy).

The paper's answer to f32 rounding is "use f64", which costs 1/24 rate on its
GPU and has NO native support on TPU.  The dominant f32 error source in AIDW
is the long accumulation chain of Σw and Σw·z over m data points (w spans
many orders of magnitude near the query).  Kahan-compensated accumulation of
the cross-tile partials recovers ~f64 accuracy at f32 cost — the TPU-native
replacement for the paper's double-precision variant.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.aidw import AIDWParams, adaptive_alpha, _sq_dists
from repro.core.knn import running_k_best


def kahan_add(s, c, x):
    """One compensated accumulation step: returns (new_sum, new_compensation)."""
    y = x - c
    t = s + y
    c_new = (t - s) - y
    return t, c_new


@partial(jax.jit, static_argnames=("params", "area", "q_chunk", "d_chunk"))
def aidw_interpolate_kahan(
    dx, dy, dz, qx, qy,
    params: AIDWParams = AIDWParams(),
    *,
    area: float,
    q_chunk: int = 1024,
    d_chunk: int = 4096,
):
    """Tiled AIDW with Kahan-compensated cross-tile Σw / Σw·z accumulators.

    Same structure as :func:`repro.core.aidw.aidw_interpolate`; only the
    weight-pass carry differs.  Returns ``(z_hat, alpha)``.
    """
    m, n = dx.shape[0], qx.shape[0]
    dtype = qx.dtype
    big = jnp.asarray(jnp.finfo(dtype).max / 4, dtype)
    m_pad = (-m) % d_chunk
    dxp = jnp.concatenate([dx, jnp.full((m_pad,), big, dtype)])
    dyp = jnp.concatenate([dy, jnp.full((m_pad,), big, dtype)])
    dzp = jnp.concatenate([dz, jnp.zeros((m_pad,), dtype)])
    n_pad = (-n) % q_chunk
    qxp = jnp.concatenate([qx, jnp.zeros((n_pad,), dtype)])
    qyp = jnp.concatenate([qy, jnp.zeros((n_pad,), dtype)])
    tiles = (dxp.reshape(-1, d_chunk), dyp.reshape(-1, d_chunk), dzp.reshape(-1, d_chunk))

    def per_q(q):
        qcx, qcy = q

        def knn_step(best, tile):
            tx, ty, _ = tile
            return running_k_best(best, _sq_dists(qcx, qcy, tx, ty)), None

        best0 = jnp.full((q_chunk, params.k), jnp.inf, dtype)
        best, _ = jax.lax.scan(knn_step, best0, tiles)
        alpha = adaptive_alpha(jnp.mean(jnp.sqrt(best), axis=1), m, area, params)
        ah = alpha * 0.5

        def w_step(carry, tile):
            sw, cw, swz, cwz, min_d2, hit_z = carry
            tx, ty, tz = tile
            d2 = _sq_dists(qcx, qcy, tx, ty)
            tiny = jnp.asarray(1e-30 if dtype == jnp.float32 else 1e-290, dtype)
            w = jnp.exp(-ah[:, None] * jnp.log(jnp.maximum(d2, tiny)))
            sw, cw = kahan_add(sw, cw, jnp.sum(w, axis=1))
            swz, cwz = kahan_add(swz, cwz, jnp.sum(w * tz[None, :], axis=1))
            tmin = jnp.min(d2, axis=1)
            thz = tz[jnp.argmin(d2, axis=1)]
            better = tmin < min_d2
            return (sw, cw, swz, cwz, jnp.where(better, tmin, min_d2), jnp.where(better, thz, hit_z)), None

        zeros = jnp.zeros((q_chunk,), dtype)
        carry0 = (zeros, zeros, zeros, zeros, jnp.full((q_chunk,), jnp.inf, dtype), zeros)
        (sw, _, swz, _, min_d2, hit_z), _ = jax.lax.scan(w_step, carry0, tiles)
        zhat = jnp.where(min_d2 <= params.exact_hit_eps, hit_z, swz / sw)
        return zhat, alpha

    zhat, alpha = jax.lax.map(per_q, (qxp.reshape(-1, q_chunk), qyp.reshape(-1, q_chunk)))
    return zhat.reshape(-1)[:n], alpha.reshape(-1)[:n]


def relative_rmse(approx, exact):
    """RMS of (approx-exact) normalised by RMS(exact) — the §Accuracy metric."""
    approx = jnp.asarray(approx, jnp.float64) if approx.dtype != jnp.float64 else approx
    e = jnp.asarray(exact, approx.dtype)
    return float(jnp.sqrt(jnp.mean((approx - e) ** 2)) / jnp.sqrt(jnp.mean(e**2)))
