"""Adaptive Inverse Distance Weighting (AIDW) — Lu & Wong (2008), as
GPU-accelerated by Mei, Xu & Xu (2015).

This module is the *mathematical* core: Eq. (2)-(6) of the paper plus a
vectorised pure-JAX interpolator that serves as the oracle for every Pallas
kernel in ``repro.kernels`` and as the single-host execution path.

Conventions
-----------
* Points are 2-D ``(x, y)`` with a scalar attribute ``z`` (the paper's
  setting; elevations etc.).
* All distances inside the hot path are *squared* distances; the paper's
  ``alpha *= 0.5`` trick (Fig. 3 line 49) is applied so weights are
  ``(d^2)^(-alpha/2) = d^(-alpha)`` without a sqrt in the weighting pass.
* The piecewise-linear alpha map implements Eq. (6) — NOT the paper's CUDA
  listing, which has a typo in the 0.3-0.5 branch (uses ``a1`` where Eq. (6)
  has ``a2``).  Eq. (6) is the continuous piecewise-linear map through
  (0.1, a1), (0.3, a2), (0.5, a3), (0.7, a4), (0.9, a5).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.knn import running_k_best

# Knots of the Eq. (6) triangular-membership map.
MU_KNOTS = (0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 1.0)

# Default five decay levels a1..a5.  The paper does not publish its values;
# these follow Lu & Wong's "categories of distance-decay value" spanning the
# usual IDW powers 0.5..4 and are configurable everywhere.
DEFAULT_ALPHA_LEVELS = (0.5, 1.0, 2.0, 3.0, 4.0)


@dataclasses.dataclass(frozen=True)
class AIDWParams:
    """Static configuration of an AIDW interpolation.

    Attributes:
      k: number of nearest neighbours entering ``r_obs`` (paper Fig. 1: 10).
      alpha_levels: the five decay levels ``a1..a5`` of Eq. (6).
      r_min, r_max: bounds of the fuzzy membership function, Eq. (5)
        ("in general ... 0.0 and 2.0").
      area: area ``A`` of the study region for Eq. (2).  ``None`` derives the
        bounding-box area of the data points (paper: unit square test data).
      exact_hit_eps: squared-distance threshold below which a query point is
        declared coincident with a data point and returns that ``z`` exactly
        (the paper's kernel would produce inf/nan here; production guard).
    """

    k: int = 10
    alpha_levels: Sequence[float] = DEFAULT_ALPHA_LEVELS
    r_min: float = 0.0
    r_max: float = 2.0
    area: float | None = None
    exact_hit_eps: float = 1e-18

    def resolve_area(self, dx, dy) -> float:
        if self.area is not None:
            return float(self.area)
        spanx = float(jnp.max(dx) - jnp.min(dx))
        spany = float(jnp.max(dy) - jnp.min(dy))
        return max(spanx * spany, 1e-30)


def expected_nn_distance(m: int, area: float):
    """Eq. (2): expected NN distance of a random pattern, r_exp = 1/(2 sqrt(m/A))."""
    return 1.0 / (2.0 * math.sqrt(m / area))


def fuzzy_membership(r_stat, r_min: float, r_max: float):
    """Eq. (5): normalise the NN statistic R(S0) to [0, 1].

    mu_R = 0 for R <= r_min; 1 for R >= r_max;
    0.5 - 0.5 cos(pi / r_max * (R - r_min)) in between.
    """
    mu = 0.5 - 0.5 * jnp.cos(jnp.pi / r_max * (r_stat - r_min))
    mu = jnp.where(r_stat <= r_min, 0.0, mu)
    mu = jnp.where(r_stat >= r_max, 1.0, mu)
    return mu


def alpha_from_mu(mu, levels: Sequence[float] = DEFAULT_ALPHA_LEVELS):
    """Eq. (6): piecewise-linear (triangular membership) map mu -> alpha.

    Linear through (0.1, a1), (0.3, a2), (0.5, a3), (0.7, a4), (0.9, a5),
    constant a1 below 0.1 and a5 above 0.9.  Equivalent to
    ``jnp.interp(mu, MU_KNOTS, [a1, a1, a2, a3, a4, a5, a5])`` but written as
    a clamped-lerp chain so the identical expression is reusable inside
    Pallas kernel bodies (jnp.interp does not lower in Mosaic).
    """
    a1, a2, a3, a4, a5 = [jnp.asarray(a, dtype=mu.dtype) for a in levels]
    alpha = a1
    for lo, aa, bb in (
        (0.1, a1, a2),
        (0.3, a2, a3),
        (0.5, a3, a4),
        (0.7, a4, a5),
    ):
        t = jnp.clip((mu - lo) * 5.0, 0.0, 1.0)  # each segment spans 0.2
        alpha = alpha * (1.0 - t) + bb * t
    return alpha


def adaptive_alpha(r_obs, m: int, area: float, params: AIDWParams):
    """Steps 1-3 of §2.2: observed-NN-mean -> R(S0) -> mu_R -> alpha."""
    r_exp = expected_nn_distance(m, area)
    r_stat = r_obs / jnp.asarray(r_exp, dtype=r_obs.dtype)
    mu = fuzzy_membership(r_stat, params.r_min, params.r_max)
    return alpha_from_mu(mu, params.alpha_levels)


def _sq_dists(qx, qy, dx, dy):
    """Pairwise squared distances, (n, 1) queries x (1, m) data -> (n, m)."""
    ddx = qx[:, None] - dx[None, :]
    ddy = qy[:, None] - dy[None, :]
    return ddx * ddx + ddy * ddy


def _weighted_average(d2, dz, alpha_half, exact_hit_eps):
    """Phase 2 (Eq. 1): w = (d^2)^(-alpha/2); exact-hit override."""
    dtype = d2.dtype
    # (d2)^(-alpha_half) via exp/log; d2 clamped away from 0 (hits handled below)
    tiny = jnp.asarray(1e-30 if dtype == jnp.float32 else 1e-290, dtype)
    w = jnp.exp(-alpha_half[:, None] * jnp.log(jnp.maximum(d2, tiny)))
    sum_w = jnp.sum(w, axis=1)
    sum_wz = jnp.sum(w * dz[None, :], axis=1)
    zhat = sum_wz / sum_w
    # exact-hit guard: query coincides with a data point
    min_d2 = jnp.min(d2, axis=1)
    hit_z = dz[jnp.argmin(d2, axis=1)]
    return jnp.where(min_d2 <= exact_hit_eps, hit_z, zhat)


def aidw_reference(dx, dy, dz, qx, qy, params: AIDWParams = AIDWParams(), *, area: float | None = None):
    """Memory-naive oracle: materialises the full (n, m) distance matrix.

    The ground truth for all kernels and the distributed path.  O(n*m) memory —
    use :func:`aidw_interpolate` for large inputs.
    Returns ``(z_hat, alpha)`` with shapes ``(n,)``.
    """
    m = dx.shape[0]
    a = area if area is not None else params.resolve_area(dx, dy)
    d2 = _sq_dists(qx, qy, dx, dy)  # (n, m)
    # k smallest squared distances per row -> r_obs over true distances
    neg_topk = jax.lax.top_k(-d2, params.k)[0]
    knn_d = jnp.sqrt(-neg_topk)
    r_obs = jnp.mean(knn_d, axis=1)
    alpha = adaptive_alpha(r_obs, m, a, params)
    zhat = _weighted_average(d2, dz, alpha * 0.5, params.exact_hit_eps)
    return zhat, alpha


def aidw_interpolate(
    dx,
    dy,
    dz,
    qx,
    qy,
    params: AIDWParams = AIDWParams(),
    *,
    area: float | None = None,
    q_chunk: int = 1024,
    d_chunk: int = 4096,
    knn: str = "brute",
    grid=None,
):
    """Production single-host AIDW: O(q_chunk * d_chunk) peak memory.

    Mirrors the two-pass structure of the paper's kernels (distances are
    computed twice) with the data-point axis tiled — this is the pure-jnp
    twin of the *tiled* kernel and the building block of the distributed
    ring version.  Returns ``(z_hat, alpha)``.

    ``knn="grid"`` replaces the Phase-1 brute-force k-best scan with the
    uniform-grid ring search of ``repro.core.grid`` (near-O(k) per query);
    Phase 2 (weights over ALL m points) is identical either way.

    This is a convenience over the plan/execute engine (``repro.engine``,
    impl="chunked"): each call builds a chunked plan and runs the jitted
    execute step.  Grid building is the one eager step (concrete occupancy);
    pass a prebuilt ``grid=`` — or hold the plan yourself — to amortise
    across query batches.  The ``knn="brute"`` path plans traceably, which
    is how the distributed sharded path reuses it inside ``shard_map``.
    """
    from repro.engine import build_plan, execute

    if knn == "brute" and grid is not None:
        raise ValueError("grid= is only meaningful with knn='grid'")
    if area is None and params.area is None:
        raise ValueError("jit path requires a static area; pass area= or set params.area")
    plan = build_plan(
        dx, dy, dz, params=params, area=area, impl="chunked", knn=knn,
        q_chunk=q_chunk, d_chunk=d_chunk, grid=grid,
    )
    return execute(plan, qx, qy)


@partial(jax.jit, static_argnames=("k", "q_chunk", "d_chunk"))
def brute_r_obs(dx, dy, qx, qy, k: int, *, q_chunk: int = 1024, d_chunk: int = 4096):
    """Phase 1, brute force: chunked running-k-best scan over ALL m data
    points -> mean k-nearest distance per query, shape ``(n,)``.

    The single implementation behind ``aidw_interpolate(knn="brute")`` and
    the benchmark baseline (``benchmarks/run.py``), and the pure-jnp twin of
    the grid path's ``grid_r_obs``."""
    n = qx.shape[0]
    dtype = qx.dtype
    big = jnp.asarray(jnp.finfo(dtype).max / 4, dtype)
    m_pad = (-dx.shape[0]) % d_chunk
    d_tiles = jnp.concatenate([dx, jnp.full((m_pad,), big, dtype)]).reshape(-1, d_chunk)
    dy_tiles = jnp.concatenate([dy, jnp.full((m_pad,), big, dtype)]).reshape(-1, d_chunk)
    n_pad = (-n) % q_chunk
    qxp = jnp.concatenate([qx, jnp.zeros((n_pad,), dtype)])
    qyp = jnp.concatenate([qy, jnp.zeros((n_pad,), dtype)])

    def per_q_chunk(q):
        qcx, qcy = q

        def knn_step(best, tile):
            tx, ty = tile
            return running_k_best(best, _sq_dists(qcx, qcy, tx, ty)), None

        best0 = jnp.full((q_chunk, k), jnp.inf, dtype)
        best, _ = jax.lax.scan(knn_step, best0, (d_tiles, dy_tiles))
        return jnp.mean(jnp.sqrt(best), axis=1)

    q_tiles = (qxp.reshape(-1, q_chunk), qyp.reshape(-1, q_chunk))
    return jax.lax.map(per_q_chunk, q_tiles).reshape(-1)[:n]


@partial(jax.jit, static_argnames=("params", "area", "q_chunk", "d_chunk"))
def _interpolate_pass2(
    dx, dy, dz, qx, qy, alpha,
    params: AIDWParams,
    *,
    area: float,
    q_chunk: int = 1024,
    d_chunk: int = 4096,
):
    """Phase 2 — the chunked weighted-average sweep with a precomputed
    per-query ``alpha``.  Shared by both knn paths (``brute_r_obs`` and
    ``grid_r_obs`` only differ in how Phase 1 finds the neighbours), so the
    Phase-2 numerics are identical by construction."""
    m = dx.shape[0]
    n = qx.shape[0]
    dtype = qx.dtype

    m_pad = (-m) % d_chunk
    big = jnp.asarray(jnp.finfo(dtype).max / 4, dtype)
    dxp = jnp.concatenate([dx, jnp.full((m_pad,), big, dtype)])
    dyp = jnp.concatenate([dy, jnp.full((m_pad,), big, dtype)])
    dzp = jnp.concatenate([dz, jnp.zeros((m_pad,), dtype)])
    n_pad = (-n) % q_chunk
    qxp = jnp.concatenate([qx, jnp.zeros((n_pad,), dtype)])
    qyp = jnp.concatenate([qy, jnp.zeros((n_pad,), dtype)])
    alphap = jnp.concatenate([alpha.astype(dtype), jnp.ones((n_pad,), dtype)])

    d_tiles = dxp.reshape(-1, d_chunk)
    dy_tiles = dyp.reshape(-1, d_chunk)
    dz_tiles = dzp.reshape(-1, d_chunk)

    def per_q_chunk(q):
        qcx, qcy, alpha_half = q

        def w_step(carry, tile):
            sum_w, sum_wz, min_d2, hit_z = carry
            tx, ty, tz = tile
            d2 = _sq_dists(qcx, qcy, tx, ty)
            tiny = jnp.asarray(1e-30 if dtype == jnp.float32 else 1e-290, dtype)
            w = jnp.exp(-alpha_half[:, None] * jnp.log(jnp.maximum(d2, tiny)))
            tile_min = jnp.min(d2, axis=1)
            tile_hit_z = tz[jnp.argmin(d2, axis=1)]
            better = tile_min < min_d2
            return (
                sum_w + jnp.sum(w, axis=1),
                sum_wz + jnp.sum(w * tz[None, :], axis=1),
                jnp.where(better, tile_min, min_d2),
                jnp.where(better, tile_hit_z, hit_z),
            ), None

        zeros = jnp.zeros((q_chunk,), dtype)
        carry0 = (zeros, zeros, jnp.full((q_chunk,), jnp.inf, dtype), zeros)
        (sum_w, sum_wz, min_d2, hit_z), _ = jax.lax.scan(
            w_step, carry0, (d_tiles, dy_tiles, dz_tiles)
        )
        return jnp.where(min_d2 <= params.exact_hit_eps, hit_z, sum_wz / sum_w)

    q_tiles = (
        qxp.reshape(-1, q_chunk),
        qyp.reshape(-1, q_chunk),
        (alphap * 0.5).reshape(-1, q_chunk),
    )
    zhat = jax.lax.map(per_q_chunk, q_tiles)
    return zhat.reshape(-1)[:n]
