"""The paper's primary contribution: the AIDW interpolation system.

Pure-JAX math (Eq. 2-6 of the paper), the brute-force kNN adapted to a
vectorised TPU form, SoA/AoaS layouts, accuracy tooling (Kahan), and the
beyond-paper multi-device ring-sharded AIDW.
"""

from repro.core.aidw import (
    AIDWParams,
    aidw_reference,
    aidw_interpolate,
    adaptive_alpha,
    alpha_from_mu,
    fuzzy_membership,
    expected_nn_distance,
)
from repro.core.grid import UniformGrid, build_grid, grid_knn, grid_r_obs
from repro.core.idw import idw_reference, idw_interpolate
from repro.core.knn import (
    k_smallest,
    running_k_best,
    paper_insertion_knn,
)
from repro.core.layouts import soa_to_aoas, aoas_to_soa, PointSet

__all__ = [
    "AIDWParams",
    "aidw_reference",
    "aidw_interpolate",
    "adaptive_alpha",
    "alpha_from_mu",
    "fuzzy_membership",
    "expected_nn_distance",
    "UniformGrid",
    "build_grid",
    "grid_knn",
    "grid_r_obs",
    "idw_reference",
    "idw_interpolate",
    "k_smallest",
    "running_k_best",
    "paper_insertion_knn",
    "soa_to_aoas",
    "aoas_to_soa",
    "PointSet",
]
