"""Brute-force k-nearest-neighbour search, two ways.

1. :func:`paper_insertion_knn` — a literal port of the paper's Fig. 1 / Fig. 3
   per-thread algorithm (fixed k-buffer, bubble/insertion maintenance).  Used
   only as a test oracle documenting the original CUDA logic.

2. :func:`running_k_best` — the TPU-native adaptation: a *branch-free,
   vectorised k-pass min-extract merge* that folds a tile of candidate
   distances into a running (rows, k) best set.  This is the exact same
   O(k * m) work the paper's insertion sort does in the worst case, but
   expressed as dense vector ops (min / cumsum / select) that lower both in
   XLA and inside Pallas Mosaic kernels (no argmin, duplicate-safe).
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def running_k_best(best, d2_tile):
    """Merge a tile of squared distances into the running k-best set.

    Args:
      best: (rows, k) current k smallest values per row, ascending not
        required (any order), +inf for empty slots.
      d2_tile: (rows, t) new candidate values.

    Returns:
      (rows, k) the k smallest of ``concat([best, d2_tile], axis=1)`` per row,
      in ascending order.

    Implementation: k passes; each pass extracts the row-min and masks out
    exactly one occurrence (first along the row, via a cumsum trick — this is
    duplicate-safe and avoids argmin, which Mosaic TPU does not lower).
    """
    k = best.shape[1]
    c = jnp.concatenate([best, d2_tile], axis=1)
    inf = jnp.asarray(jnp.inf, c.dtype)
    outs = []
    for _ in range(k):
        v = jnp.min(c, axis=1, keepdims=True)  # (rows, 1)
        outs.append(v)
        eq = (c == v).astype(jnp.int32)
        first = (jnp.cumsum(eq, axis=1) == 1) & (eq == 1)  # first occurrence only
        c = jnp.where(first, inf, c)
    return jnp.concatenate(outs, axis=1)


def k_smallest(values, k: int):
    """k smallest entries of the last axis, ascending (thin top_k wrapper)."""
    import jax

    neg, _ = jax.lax.top_k(-values, k)
    return -neg


def paper_insertion_knn(d: np.ndarray, k: int) -> np.ndarray:
    """Fig. 1 / Fig. 3 lines 11-32 of the paper, verbatim (numpy, one query).

    Args:
      d: (m,) squared distances from one interpolated point to all data points.
      k: neighbourhood size.

    Returns:
      (k,) the k smallest squared distances, ascending.
    """
    m = d.shape[0]
    buf = d[:k].copy()
    # "sort the first k distances in ascending order" (bubble sort, Fig. 3)
    for i in range(k - 1):
        for j in range(k - 1 - i):
            if buf[j] > buf[j + 1]:
                buf[j], buf[j + 1] = buf[j + 1], buf[j]
    # stream the remaining m-k candidates
    for i in range(k, m):
        dist = d[i]
        if dist < buf[k - 1]:
            buf[k - 1] = dist
            # neighbouring compare-and-swap back to sorted order
            for j in range(k - 2, -1, -1):
                if buf[j] > buf[j + 1]:
                    buf[j], buf[j + 1] = buf[j + 1], buf[j]
                else:
                    break
    return buf
