"""Multi-device / multi-pod AIDW — beyond the paper's single GPU.

Sharding scheme (DESIGN.md §2, last row):

* **Query points** are embarrassingly parallel (the paper's own observation)
  → sharded over every mesh axis, no communication.
* **Data points** at production scale (10^8+) no longer fit one chip →
  sharded too.  The kNN phase and the Σw/Σw·z phase are both *associative*
  reductions over data shards, so a **ring** of ``lax.ppermute`` steps rotates
  the data shards around the mesh axis while each query shard folds the
  visiting shard into its running state (k-best merge / weight partials).

Communication/compute overlap: the next shard's ppermute is issued *before*
the local fold, so XLA's async collective-permute runs concurrently with the
distance computation — the TPU analogue of CUDA stream overlap, and the same
schedule ring-attention uses.

Exactness: k-best merge and compensated sums are associative up to fp
rounding — results match the single-device kernels to tolerance (tested with
8 simulated devices in ``tests/distributed``).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.aidw import AIDWParams, adaptive_alpha, _sq_dists
from repro.core.knn import running_k_best


def shard_map_compat(**kw):
    """Version-portable ``shard_map`` decorator (same policy as the
    compiler-params shim in ``kernels/_common.py``): newer jax exposes
    ``jax.shard_map`` with ``check_vma``; 0.4.x ships
    ``jax.experimental.shard_map.shard_map`` with the equivalent knob named
    ``check_rep`` and no vma typing."""
    if hasattr(jax, "shard_map"):
        return functools.partial(jax.shard_map, **kw)
    from jax.experimental.shard_map import shard_map

    if "check_vma" in kw:
        kw["check_rep"] = kw.pop("check_vma")
    return functools.partial(shard_map, **kw)


def _pvary(x, axes):
    """``lax.pvary`` marks a value device-varying for the vma type system;
    on jax versions without it (no vma typing) it is the identity."""
    fn = getattr(jax.lax, "pvary", None)
    return x if fn is None else fn(x, axes)


def _ring_perm(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def _fold_knn(best, qx_l, qy_l, cx, cy, q_chunk, d_chunk):
    """Merge the visiting data shard into the running per-query k-best,
    bounded-memory: queries mapped in q_chunk rows, data scanned in d_chunk
    columns -> peak temp (q_chunk, d_chunk)."""
    k = best.shape[1]
    dxt = (cx.reshape(-1, d_chunk), cy.reshape(-1, d_chunk))

    def per_q(args):
        qcx, qcy, b0 = args

        def step(b, tile):
            tx, ty = tile
            return running_k_best(b, _sq_dists(qcx, qcy, tx, ty)), None

        b, _ = jax.lax.scan(step, b0, dxt)
        return b

    out = jax.lax.map(
        per_q,
        (qx_l.reshape(-1, q_chunk), qy_l.reshape(-1, q_chunk), best.reshape(-1, q_chunk, k)),
    )
    return out.reshape(-1, k)


def _fold_weights(carry, ah, qx_l, qy_l, cx, cy, cz, q_chunk, d_chunk):
    """Accumulate this shard's weight partials (sum_w, sum_wz, min_d2, hit_z)."""
    sw, swz, min_d2, hit_z = carry
    dtype = qx_l.dtype
    tiles = (cx.reshape(-1, d_chunk), cy.reshape(-1, d_chunk), cz.reshape(-1, d_chunk))

    def per_q(args):
        qcx, qcy, ahc, swc, swzc, mdc, hzc = args

        def step(c, tile):
            s, z, md, hz = c
            tx, ty, tz = tile
            d2 = _sq_dists(qcx, qcy, tx, ty)
            tiny = jnp.asarray(1e-30 if dtype == jnp.float32 else 1e-290, dtype)
            w = jnp.exp(-ahc[:, None] * jnp.log(jnp.maximum(d2, tiny)))
            tmin = jnp.min(d2, axis=1)
            thz = tz[jnp.argmin(d2, axis=1)]
            better = tmin < md
            return (
                s + jnp.sum(w, axis=1),
                z + jnp.sum(w * tz[None, :], axis=1),
                jnp.where(better, tmin, md),
                jnp.where(better, thz, hz),
            ), None

        c, _ = jax.lax.scan(step, (swc, swzc, mdc, hzc), tiles)
        return c

    r = lambda a: a.reshape(-1, q_chunk)
    out = jax.lax.map(per_q, (r(qx_l), r(qy_l), r(ah), r(sw), r(swz), r(min_d2), r(hit_z)))
    return tuple(a.reshape(-1) for a in out)


def ring_aidw(
    mesh: Mesh,
    dx, dy, dz, qx, qy,
    *,
    params: AIDWParams,
    area: float,
    axis_names: Sequence[str] | str | None = None,
    q_chunk: int = 1024,
    d_chunk: int = 2048,
):
    """Fully-sharded AIDW over ``mesh``.

    Queries AND data are sharded over the flattened ``axis_names`` (default:
    all mesh axes).  Global sizes must divide the total device count (the
    launcher pads).  Per-device temp memory is bounded by the
    (q_chunk, d_chunk) distance tile regardless of shard sizes.
    Returns ``(z_hat, alpha)`` sharded like the queries.
    """
    if axis_names is None:
        axis_names = tuple(mesh.axis_names)
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    axes = tuple(axis_names)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    m_total = dx.shape[0]
    k = params.k
    spec = P(axes)
    qc = min(q_chunk, qx.shape[0] // nshards)
    dc = min(d_chunk, dx.shape[0] // nshards)

    @shard_map_compat(
        mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec),
        out_specs=(spec, spec),
    )
    def body(dx_l, dy_l, dz_l, qx_l, qy_l):
        nq_l = qx_l.shape[0]
        dtype = qx_l.dtype
        perm = _ring_perm(nshards)

        # ---- phase 1: ring kNN ----
        def knn_step(i, carry):
            best, cx, cy = carry
            # issue the rotation first so the collective-permute overlaps the fold
            nx = jax.lax.ppermute(cx, axes, perm)
            ny = jax.lax.ppermute(cy, axes, perm)
            best = _fold_knn(best, qx_l, qy_l, cx, cy, qc, dc)
            return best, nx, ny

        best0 = _pvary(jnp.full((nq_l, k), jnp.inf, dtype), axes)
        best, _, _ = jax.lax.fori_loop(0, nshards, knn_step, (best0, dx_l, dy_l))
        alpha = adaptive_alpha(jnp.mean(jnp.sqrt(best), axis=1), m_total, area, params)
        ah = alpha * 0.5

        # ---- phase 2: ring weighting ----
        def w_step(i, carry):
            acc, cx, cy, cz = carry
            nx = jax.lax.ppermute(cx, axes, perm)
            ny = jax.lax.ppermute(cy, axes, perm)
            nz = jax.lax.ppermute(cz, axes, perm)
            acc = _fold_weights(acc, ah, qx_l, qy_l, cx, cy, cz, qc, dc)
            return acc, nx, ny, nz

        zeros = _pvary(jnp.zeros((nq_l,), dtype), axes)
        inf0 = _pvary(jnp.full((nq_l,), jnp.inf, dtype), axes)
        acc0 = (zeros, zeros, inf0, zeros)
        (sw, swz, min_d2, hit_z), _, _, _ = jax.lax.fori_loop(
            0, nshards, w_step, (acc0, dx_l, dy_l, dz_l)
        )
        zhat = jnp.where(min_d2 <= params.exact_hit_eps, hit_z, swz / sw)
        return zhat, alpha

    return body(dx, dy, dz, qx, qy)


def ring_aidw_rotate_queries(
    mesh: Mesh,
    dx, dy, dz, qx, qy,
    *,
    params: AIDWParams,
    area: float,
    axis_names: Sequence[str] | str | None = None,
    q_chunk: int = 1024,
    d_chunk: int = 2048,
):
    """§Perf-AIDW hillclimb: rotate the QUERIES (with their running state)
    around the ring instead of the data shards.

    Ring payload per step: phase 1 moves (qx, qy, k-best) = (2+k)*4 B/query;
    phase 2 moves (qx, qy, alpha, sum_w, sum_wz, min_d2, hit_z) = 7*4 B/query.
    The data-rotating baseline moves 8 B/point (phase 1) + 12 B/point
    (phase 2).  For the production workload (n = 2^24 queries, m = 2^27
    points) that is a ~4.6x collective-byte reduction — data points never
    leave their shard.  Exactness is unchanged (same folds, different hand).
    Results return in the ORIGINAL query sharding (the ring walks each query
    slab through every shard and back home: nshards rotations = identity).
    """
    if axis_names is None:
        axis_names = tuple(mesh.axis_names)
    if isinstance(axis_names, str):
        axis_names = (axis_names,)
    axes = tuple(axis_names)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    m_total = dx.shape[0]
    k = params.k
    spec = P(axes)
    qc = min(q_chunk, qx.shape[0] // nshards)
    dc = min(d_chunk, dx.shape[0] // nshards)

    @shard_map_compat(
        mesh=mesh,
        in_specs=(spec, spec, spec, spec, spec),
        out_specs=(spec, spec),
    )
    def body(dx_l, dy_l, dz_l, qx_l, qy_l):
        nq_l = qx_l.shape[0]
        dtype = qx_l.dtype
        perm = _ring_perm(nshards)

        # ---- phase 1: queries + k-best circulate ----
        def knn_step(i, carry):
            cqx, cqy, best = carry
            nqx = jax.lax.ppermute(cqx, axes, perm)
            nqy = jax.lax.ppermute(cqy, axes, perm)
            best = _fold_knn(best, cqx, cqy, dx_l, dy_l, qc, dc)
            nbest = jax.lax.ppermute(best, axes, perm)
            return nqx, nqy, nbest

        best0 = _pvary(jnp.full((nq_l, k), jnp.inf, dtype), axes)
        qx_r, qy_r, best = jax.lax.fori_loop(0, nshards, knn_step, (qx_l, qy_l, best0))
        # after nshards rotations every slab is home again
        alpha = adaptive_alpha(jnp.mean(jnp.sqrt(best), axis=1), m_total, area, params)
        ah = alpha * 0.5

        # ---- phase 2: queries + weight partials circulate ----
        def w_step(i, carry):
            cqx, cqy, cah, acc = carry
            nqx = jax.lax.ppermute(cqx, axes, perm)
            nqy = jax.lax.ppermute(cqy, axes, perm)
            nah = jax.lax.ppermute(cah, axes, perm)
            acc = _fold_weights(acc, cah, cqx, cqy, dx_l, dy_l, dz_l, qc, dc)
            nacc = jax.tree.map(lambda a: jax.lax.ppermute(a, axes, perm), acc)
            return nqx, nqy, nah, nacc

        zeros = _pvary(jnp.zeros((nq_l,), dtype), axes)
        inf0 = _pvary(jnp.full((nq_l,), jnp.inf, dtype), axes)
        acc0 = (zeros, zeros, inf0, zeros)
        _, _, _, (sw, swz, min_d2, hit_z) = jax.lax.fori_loop(
            0, nshards, w_step, (qx_r, qy_r, ah, acc0)
        )
        zhat = jnp.where(min_d2 <= params.exact_hit_eps, hit_z, swz / sw)
        return zhat, alpha

    return body(dx, dy, dz, qx, qy)


def sharded_queries_aidw(
    mesh: Mesh, dx, dy, dz, qx, qy, *, params: AIDWParams, area: float,
    q_chunk: int = 1024, d_chunk: int = 8192,
):
    """Simpler production mode when the data set fits per-chip: data points
    replicated, queries sharded over all axes — zero communication (the
    paper's "naturally parallel" observation, lifted to a pod).  The local
    solve goes through the plan/execute engine (a chunked-brute plan builds
    traceably, so each shard plans *inside* ``shard_map``), which keeps the
    padding/sentinel/chunking logic identical to the single-host path."""
    from repro.engine import build_plan, execute

    axes = tuple(mesh.axis_names)
    qspec = P(axes)
    nshards = 1
    for a in axes:
        nshards *= mesh.shape[a]
    qc = min(q_chunk, qx.shape[0] // nshards)
    dc = min(d_chunk, dx.shape[0])

    @shard_map_compat(
        mesh=mesh,
        in_specs=(P(), P(), P(), qspec, qspec),
        out_specs=(qspec, qspec),
        check_vma=False,  # collective-free body; the tiled interpolator's
        # scan carries are created unvarying and trip the vma typing
    )
    def body(dx_r, dy_r, dz_r, qx_l, qy_l):
        plan = build_plan(
            dx_r, dy_r, dz_r, params=params, area=area, impl="chunked",
            q_chunk=qc, d_chunk=dc,
        )
        return execute(plan, qx_l, qy_l)

    return body(dx, dy, dz, qx, qy)
