"""Standard IDW (Shepard 1968) — the paper's comparison baseline (§5.3.1).

Identical weighting pass to AIDW but with a user-fixed constant power alpha,
and no kNN pass (one distance sweep instead of two).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def idw_reference(dx, dy, dz, qx, qy, alpha: float = 2.0, *, exact_hit_eps: float = 1e-18):
    """Memory-naive oracle, full (n, m) distance matrix. Returns (n,) z_hat."""
    ddx = qx[:, None] - dx[None, :]
    ddy = qy[:, None] - dy[None, :]
    d2 = ddx * ddx + ddy * ddy
    dtype = d2.dtype
    tiny = jnp.asarray(1e-30 if dtype == jnp.float32 else 1e-290, dtype)
    w = jnp.exp(-(alpha * 0.5) * jnp.log(jnp.maximum(d2, tiny)))
    zhat = jnp.sum(w * dz[None, :], axis=1) / jnp.sum(w, axis=1)
    min_d2 = jnp.min(d2, axis=1)
    hit_z = dz[jnp.argmin(d2, axis=1)]
    return jnp.where(min_d2 <= exact_hit_eps, hit_z, zhat)


@partial(jax.jit, static_argnames=("alpha", "q_chunk", "d_chunk"))
def idw_interpolate(dx, dy, dz, qx, qy, alpha: float = 2.0, *, q_chunk: int = 1024, d_chunk: int = 4096):
    """Tiled single-host IDW (single distance sweep). Returns (n,) z_hat."""
    m, n = dx.shape[0], qx.shape[0]
    dtype = qx.dtype
    big = jnp.asarray(jnp.finfo(dtype).max / 4, dtype)
    m_pad = (-m) % d_chunk
    dxp = jnp.concatenate([dx, jnp.full((m_pad,), big, dtype)])
    dyp = jnp.concatenate([dy, jnp.full((m_pad,), big, dtype)])
    dzp = jnp.concatenate([dz, jnp.zeros((m_pad,), dtype)])
    n_pad = (-n) % q_chunk
    qxp = jnp.concatenate([qx, jnp.zeros((n_pad,), dtype)])
    qyp = jnp.concatenate([qy, jnp.zeros((n_pad,), dtype)])
    tiles = (dxp.reshape(-1, d_chunk), dyp.reshape(-1, d_chunk), dzp.reshape(-1, d_chunk))

    def per_q(q):
        qcx, qcy = q

        def step(carry, tile):
            sum_w, sum_wz, min_d2, hit_z = carry
            tx, ty, tz = tile
            ddx = qcx[:, None] - tx[None, :]
            ddy = qcy[:, None] - ty[None, :]
            d2 = ddx * ddx + ddy * ddy
            tiny = jnp.asarray(1e-30 if dtype == jnp.float32 else 1e-290, dtype)
            w = jnp.exp(-(alpha * 0.5) * jnp.log(jnp.maximum(d2, tiny)))
            tmin = jnp.min(d2, axis=1)
            thz = tz[jnp.argmin(d2, axis=1)]
            better = tmin < min_d2
            return (
                sum_w + jnp.sum(w, axis=1),
                sum_wz + jnp.sum(w * tz[None, :], axis=1),
                jnp.where(better, tmin, min_d2),
                jnp.where(better, thz, hit_z),
            ), None

        zeros = jnp.zeros((q_chunk,), dtype)
        (sw, swz, md, hz), _ = jax.lax.scan(
            step, (zeros, zeros, jnp.full((q_chunk,), jnp.inf, dtype), zeros), tiles
        )
        return jnp.where(md <= 1e-18, hz, swz / sw)

    out = jax.lax.map(per_q, (qxp.reshape(-1, q_chunk), qyp.reshape(-1, q_chunk)))
    return out.reshape(-1)[:n]
