"""Uniform-grid spatial partition for fast kNN — the Phase-1 accelerator.

The paper's AIDW Phase 1 computes ``r_obs`` (mean distance to the k nearest
data points) by brute-force scanning all m data points per query.  The
follow-up work (arXiv:1601.05904, "Improving GPU-accelerated Adaptive IDW
Interpolation Algorithm Using Fast kNN Search") replaces that scan with a
uniform grid: bucket the data points into ``gx x gy`` cells, then search
outward from the query's home cell in expanding Chebyshev rings until the
running kth-best distance proves no unvisited cell can hold a closer point.

Layout (DESIGN.md §4): points are sorted by cell id and scattered into a
*padded* ``(n_cells + 1, cap)`` array (``cap`` = max cell occupancy).  Empty
slots hold a large sentinel coordinate whose squared distance overflows to
+inf, so they can never enter a k-best set; row ``n_cells`` is an
all-sentinel row used as the gather target for out-of-grid / masked cell
ids — every gather is in-bounds and branch-free.  A ``(gy+1, gx+1)``
integral image of the occupancy counts answers "how many points in the
(2r+1)^2 block around cell C" in O(1), which powers both the empty-ring
skip of :func:`grid_knn` and the occupancy-only :func:`safe_radius` bound
used by the Pallas grid kernel.

Ring-search invariant (the correctness contract, exercised by the property
tests): for a query whose *clamped* home cell is C, every point in a cell
at Chebyshev distance ``c`` from C lies at Euclidean distance
``>= (c - 1) * min(cell_w, cell_h)`` from the query.  Hence once rings
``0..r`` are merged, the search may stop as soon as
``kth_best^2 <= (r * min(cell_w, cell_h))^2`` — all unvisited cells are at
Chebyshev ``>= r + 1``.  The bound survives queries *outside* the grid:
clamping the home cell only ever moves it toward the query along each axis,
so per-axis gaps to other cells only grow.

Everything below is pure jnp + lax (no Pallas) so it lowers identically
under jit, eagerly, and in interpret-mode comparisons.  ``build_grid`` is
the one eager-only entry point: the padded capacity is data-dependent.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.knn import running_k_best
from repro.core.layouts import coord_sentinel  # re-export: the one sentinel definition

# Default mean points-per-cell the auto-resolution aims for.  ~16 keeps the
# home 3x3 block at ~144 expected points — comfortably above the paper's
# k=10 — while cells stay small enough that the stop bound fires on ring 1.
DEFAULT_OCCUPANCY = 16.0

# Cells per axis are clamped here: beyond this the integral image and the
# per-ring bookkeeping start to dominate the win over brute force.
MAX_CELLS_PER_AXIS = 512


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class UniformGrid:
    """Padded uniform-grid bucketing of an attributed 2-D point set.

    Attributes:
      gx, gy: cells per axis (static).
      cap: padded per-cell capacity = max occupancy (static).
      origin: (2,) lower-left corner ``(x0, y0)``.
      cell_size: (2,) ``(cell_w, cell_h)``.
      cell_x, cell_y, cell_z: ``(gx*gy + 1, cap)`` padded per-cell point
        data; coordinate pad slots hold the +inf-overflow sentinel, ``z``
        pad slots hold 0.  The final row is all-sentinel (masked gathers).
      counts: ``(gy, gx)`` int32 occupancy.
      cum: ``(gy+1, gx+1)`` int32 integral image of ``counts``.
      pt_x, pt_y, pt_z: ``(m + 1,)`` CSR twin of the padded layout — the
        points sorted by cell id, with one trailing sentinel slot (index
        ``m``) so masked gathers stay in-bounds.  Cell ``c`` owns the
        contiguous run ``pt_*[starts[c]:starts[c+1]]``; a *row* of cells
        ``(y, xlo..xhi)`` is likewise one contiguous run — the property the
        static-shape candidate gather of ``repro.engine`` exploits.
      starts: ``(gx*gy + 1,)`` int32 CSR row pointers into ``pt_*``.
    """

    gx: int
    gy: int
    cap: int
    origin: jnp.ndarray
    cell_size: jnp.ndarray
    cell_x: jnp.ndarray
    cell_y: jnp.ndarray
    cell_z: jnp.ndarray
    counts: jnp.ndarray
    cum: jnp.ndarray
    pt_x: jnp.ndarray
    pt_y: jnp.ndarray
    pt_z: jnp.ndarray
    starts: jnp.ndarray

    @property
    def n_cells(self) -> int:
        return self.gx * self.gy

    @property
    def n_points(self) -> int:
        return self.pt_x.shape[0] - 1

    def tree_flatten(self):
        children = (self.origin, self.cell_size, self.cell_x, self.cell_y,
                    self.cell_z, self.counts, self.cum, self.pt_x, self.pt_y,
                    self.pt_z, self.starts)
        return children, (self.gx, self.gy, self.cap)

    @classmethod
    def tree_unflatten(cls, aux, children):
        gx, gy, cap = aux
        return cls(gx, gy, cap, *children)




def build_grid(
    dx, dy, dz=None, *,
    gx: int | None = None,
    gy: int | None = None,
    target_occupancy: float = DEFAULT_OCCUPANCY,
    bounds: tuple[float, float, float, float] | None = None,
) -> UniformGrid:
    """Bucket points into a uniform grid with a ragged-to-padded cell layout.

    Eager-only (the padded capacity is ``max(counts)``, a concrete value);
    call it once per dataset outside jit and pass the resulting pytree into
    jitted consumers.

    Args:
      dx, dy: (m,) point coordinates.  dz: optional (m,) attribute.
      gx, gy: cells per axis; default ``ceil(sqrt(m / target_occupancy))``
        per axis, clamped to [1, 512].
      bounds: ``(x0, x1, y0, y1)`` grid extent; defaults to the data bbox.
    """
    m = int(dx.shape[0])
    dtype = jnp.asarray(dx).dtype
    if dz is None:
        dz = jnp.zeros((m,), dtype)
    if bounds is None:
        x0, x1 = float(jnp.min(dx)), float(jnp.max(dx))
        y0, y1 = float(jnp.min(dy)), float(jnp.max(dy))
    else:
        x0, x1, y0, y1 = map(float, bounds)
    if gx is None or gy is None:
        g = max(1, min(MAX_CELLS_PER_AXIS, math.ceil(math.sqrt(m / max(target_occupancy, 1e-9)))))
        gx = gx or g
        gy = gy or g
    # degenerate spans (all points on a line/point) still need a positive cell
    span_x = max(x1 - x0, 1e-12)
    span_y = max(y1 - y0, 1e-12)
    origin = jnp.asarray([x0, y0], jnp.float32)
    cell_size = jnp.asarray([span_x / gx, span_y / gy], jnp.float32)

    n_cells = gx * gy
    cx = jnp.clip(jnp.floor((jnp.asarray(dx) - x0) / cell_size[0]).astype(jnp.int32), 0, gx - 1)
    cy = jnp.clip(jnp.floor((jnp.asarray(dy) - y0) / cell_size[1]).astype(jnp.int32), 0, gy - 1)
    cid = cy * gx + cx

    counts_flat = jnp.zeros((n_cells,), jnp.int32).at[cid].add(1)
    cap = max(int(jnp.max(counts_flat)), 1)

    order = jnp.argsort(cid, stable=True)
    cid_s = cid[order]
    starts = jnp.searchsorted(cid_s, jnp.arange(n_cells + 1, dtype=cid_s.dtype)).astype(jnp.int32)
    rank = jnp.arange(m, dtype=jnp.int32) - starts[cid_s]

    big = coord_sentinel(dtype)
    dx_s, dy_s, dz_s = jnp.asarray(dx)[order], jnp.asarray(dy)[order], jnp.asarray(dz)[order]
    cell_x = jnp.full((n_cells + 1, cap), big, dtype).at[cid_s, rank].set(dx_s)
    cell_y = jnp.full((n_cells + 1, cap), big, dtype).at[cid_s, rank].set(dy_s)
    cell_z = jnp.zeros((n_cells + 1, cap), dtype).at[cid_s, rank].set(dz_s)
    # CSR twin: sorted points + row pointers, one trailing sentinel slot
    pt_x = jnp.concatenate([dx_s, jnp.full((1,), big, dtype)])
    pt_y = jnp.concatenate([dy_s, jnp.full((1,), big, dtype)])
    pt_z = jnp.concatenate([dz_s, jnp.zeros((1,), dtype)])

    counts = counts_flat.reshape(gy, gx)
    cum = jnp.zeros((gy + 1, gx + 1), jnp.int32)
    cum = cum.at[1:, 1:].set(jnp.cumsum(jnp.cumsum(counts, axis=0), axis=1))
    return UniformGrid(gx, gy, cap, origin, cell_size, cell_x, cell_y, cell_z,
                       counts, cum, pt_x, pt_y, pt_z, starts)


def cell_of(grid: UniformGrid, x, y):
    """Clamped home-cell indices ``(cx, cy)`` for query coordinates."""
    cx = jnp.clip(jnp.floor((x - grid.origin[0]) / grid.cell_size[0]).astype(jnp.int32), 0, grid.gx - 1)
    cy = jnp.clip(jnp.floor((y - grid.origin[1]) / grid.cell_size[1]).astype(jnp.int32), 0, grid.gy - 1)
    return cx, cy


def block_count(grid: UniformGrid, cx, cy, r):
    """Points inside the (2r+1)^2 cell block centred at ``(cx, cy)``, O(1)
    via the integral image.  All args broadcastable int32."""
    xlo = jnp.clip(cx - r, 0, grid.gx)
    xhi = jnp.clip(cx + r + 1, 0, grid.gx)
    ylo = jnp.clip(cy - r, 0, grid.gy)
    yhi = jnp.clip(cy + r + 1, 0, grid.gy)
    c = grid.cum
    return c[yhi, xhi] - c[ylo, xhi] - c[yhi, xlo] + c[ylo, xlo]


def cover_radius(grid: UniformGrid, cx, cy):
    """Ring radius at which the block around ``(cx, cy)`` covers the grid."""
    return jnp.maximum(
        jnp.maximum(cx, grid.gx - 1 - cx), jnp.maximum(cy, grid.gy - 1 - cy)
    )


def _ring_cell_offset(r, i):
    """Decode perimeter index ``i in [0, 8r)`` of Chebyshev ring ``r`` into a
    cell offset ``(ox, oy)``; ring 0 is the single home cell."""
    rr = jnp.maximum(r, 1)
    side = i // (2 * rr)
    t = i % (2 * rr)
    ox = jnp.where(side == 0, -rr + t, jnp.where(side == 1, rr, jnp.where(side == 2, rr - t, -rr)))
    oy = jnp.where(side == 0, -rr, jnp.where(side == 1, -rr + t, jnp.where(side == 2, rr, rr - t)))
    ox = jnp.where(r == 0, 0, ox)
    oy = jnp.where(r == 0, 0, oy)
    return ox, oy


@functools.partial(jax.jit, static_argnames=("k",))
def grid_knn(grid: UniformGrid, qx, qy, k: int, active=None):
    """Exact k nearest neighbours via expanding ring search.

    Returns ``(n, k)`` squared distances, ascending.  If the grid holds
    fewer than ``k`` points the tail is +inf (callers validate ``m >= k``).

    Batched: one global ``while_loop``; each iteration folds ONE cell of the
    current ring into every live query's k-best set (a ``(k, k+cap)``
    branch-free merge), with two shortcuts driven by the integral image:
    entirely-empty rings complete in a single iteration, and a query stops
    as soon as the ring bound proves its k-best is final (see module
    docstring for the invariant).

    ``active`` (optional bool ``(n,)``) masks the search to a subset of
    queries: inactive queries start ``done`` (their rows stay +inf) and add
    no loop iterations, so the cost is bounded by the *active* queries'
    ring work — an all-inactive batch exits in zero iterations.  This is
    what the engine's per-block overflow blend uses to ring-search only the
    queries whose block exceeded the plan's static candidate capacity.
    """
    n = qx.shape[0]
    dtype = qx.dtype
    gx, gy = grid.gx, grid.gy
    cx, cy = cell_of(grid, qx, qy)
    cell_min = jnp.minimum(grid.cell_size[0], grid.cell_size[1]).astype(dtype)
    r_cover = cover_radius(grid, cx, cy)
    qxc, qyc = qx[:, None], qy[:, None]

    def cond(state):
        return ~jnp.all(state[3])

    def body(state):
        best, r, i, done = state
        ring_n = jnp.where(r == 0, 1, 8 * r)
        inner = jnp.where(r > 0, block_count(grid, cx, cy, r - 1), 0)
        ring_cnt = block_count(grid, cx, cy, r) - inner
        at_end = i >= ring_n
        skip = (ring_cnt == 0) & (i == 0)  # whole ring empty: complete in one step
        scan_now = (~done) & (~at_end) & (~skip)

        ox, oy = _ring_cell_offset(r, i)
        ccx, ccy = cx + ox, cy + oy
        valid = scan_now & (ccx >= 0) & (ccx < gx) & (ccy >= 0) & (ccy < gy)
        cid = jnp.where(valid, ccy * gx + ccx, grid.n_cells)  # sentinel row
        px = grid.cell_x[cid]
        py = grid.cell_y[cid]
        d2 = (qxc - px) ** 2 + (qyc - py) ** 2  # pad slots overflow to +inf
        best = jnp.where(scan_now[:, None], running_k_best(best, d2), best)

        completing = (~done) & (at_end | skip)
        kth = best[:, k - 1]
        bound = r.astype(dtype) * cell_min
        stop = completing & ((kth <= bound * bound) | (r >= r_cover))
        done = done | stop
        adv = completing & (~stop)
        r = jnp.where(adv, r + 1, r)
        i = jnp.where(adv, 0, jnp.where(scan_now, i + 1, i))
        return best, r, i, done

    done0 = jnp.zeros((n,), bool) if active is None else ~active
    state = (
        jnp.full((n, k), jnp.inf, dtype),
        jnp.zeros((n,), jnp.int32),
        jnp.zeros((n,), jnp.int32),
        done0,
    )
    best, _, _, _ = jax.lax.while_loop(cond, body, state)
    return best


@functools.partial(jax.jit, static_argnames=("k",))
def grid_r_obs(grid: UniformGrid, qx, qy, k: int, active=None):
    """Phase-1 statistic: mean distance to the k nearest data points.
    Inactive queries (see :func:`grid_knn`) return +inf."""
    return jnp.mean(jnp.sqrt(grid_knn(grid, qx, qy, k, active)), axis=1)


def required_radius(grid: UniformGrid, cx, cy, k: int):
    """Smallest ring radius whose (2r+1)^2 block holds >= k points (or the
    whole grid).  Occupancy-only — O(max radius) integral-image lookups."""
    n = cx.shape[0]
    want = jnp.minimum(k, grid.cum[-1, -1])
    r_cover = cover_radius(grid, cx, cy)

    def cond(state):
        return ~jnp.all(state[1])

    def body(state):
        r, found = state
        ok = (block_count(grid, cx, cy, r) >= want) | (r >= r_cover)
        return jnp.where(found | ok, r, r + 1), found | ok

    r, _ = jax.lax.while_loop(
        cond, body, (jnp.zeros((n,), jnp.int32), jnp.zeros((n,), bool))
    )
    return r


def safe_radius(grid: UniformGrid, qx, qy, k: int):
    """Ring radius guaranteed (from occupancy alone, no distances) to contain
    the true k nearest neighbours of the query at ``(qx, qy)``.

    With ``r_need`` from :func:`required_radius`, every point of that block
    is within ``Dx = ex + (r_need + 1) * cell_w`` / ``Dy = ...`` of the
    query per axis, where ``(ex, ey)`` is the query's overhang beyond its
    clamped home cell (0 inside the grid) — so the kth-NN distance is
    ``<= D = sqrt(Dx^2 + Dy^2)``.  Conversely every cell at Chebyshev ``c``
    is at distance ``>= sqrt(ex^2 + ey^2 + ((c-1) * cell_min)^2)`` (the
    overhang adds to the axis gap for every in-grid cell), so only cells
    with ``(c - 1) * cell_min < sqrt(D^2 - e^2)`` can matter.  For in-grid
    queries this reduces to the plain ``(r_need + 1) * diag`` bound; for
    out-of-grid queries the overhang correction is what keeps the guarantee
    sound (the naive bound misses neighbours once the query is more than
    about a cell outside the bbox).  Used by the Pallas grid kernel, whose
    candidate neighbourhoods must be fixed before any distance is computed.

    Returns ``(cx, cy, r_safe)`` (the clamped home cells are needed by every
    caller anyway).
    """
    cx, cy = cell_of(grid, qx, qy)
    r_need = required_radius(grid, cx, cy, k)
    return cx, cy, safe_radius_from_need(grid, qx, qy, cx, cy, r_need)


def safe_radius_from_need(grid: UniformGrid, qx, qy, cx, cy, r_need):
    """The closed-form half of :func:`safe_radius`: given each query's
    clamped home cell and its occupancy-only ``required_radius``, return the
    containment-safe ring radius.  Split out so jitted consumers (the
    plan/execute engine) can replace the ``required_radius`` while-loop with
    a plan-time per-cell table lookup and keep the overhang correction
    exact for out-of-grid queries."""
    cw, ch = grid.cell_size[0], grid.cell_size[1]
    cmin = jnp.minimum(cw, ch)
    # per-axis overhang beyond the clamped home cell's span (0 inside)
    x_lo = grid.origin[0] + cx.astype(cw.dtype) * cw
    y_lo = grid.origin[1] + cy.astype(ch.dtype) * ch
    ex = jnp.maximum(jnp.maximum(x_lo - qx, qx - (x_lo + cw)), 0.0).astype(jnp.float32)
    ey = jnp.maximum(jnp.maximum(y_lo - qy, qy - (y_lo + ch)), 0.0).astype(jnp.float32)
    dx_bound = ex + (r_need.astype(jnp.float32) + 1.0) * cw
    dy_bound = ey + (r_need.astype(jnp.float32) + 1.0) * ch
    slack = jnp.sqrt(jnp.maximum(dx_bound * dx_bound + dy_bound * dy_bound
                                 - ex * ex - ey * ey, 0.0))
    r_safe = jnp.floor(slack / cmin).astype(jnp.int32) + 1
    return jnp.clip(jnp.maximum(r_safe, r_need), 0, cover_radius(grid, cx, cy))


def required_radius_table(grid: UniformGrid, k: int):
    """``(gy, gx)`` int32 table of :func:`required_radius` for every cell.

    Occupancy-only, so it depends on the data alone — computed once at plan
    time (eagerly) and looked up per query inside the traced execute step,
    replacing the data-dependent while-loop on the hot path."""
    ys, xs = jnp.meshgrid(
        jnp.arange(grid.gy, dtype=jnp.int32),
        jnp.arange(grid.gx, dtype=jnp.int32),
        indexing="ij",
    )
    return required_radius(grid, xs.reshape(-1), ys.reshape(-1), k).reshape(grid.gy, grid.gx)


def static_cell_radius(grid: UniformGrid, r_need_table):
    """Per-cell safe ring radius for a query anywhere *inside* the cell
    (overhang 0) — the worst case the plan's static candidate capacity must
    cover for in-bbox queries.  Vectorised twin of the in-grid branch of
    :func:`safe_radius_from_need`."""
    cw, ch = grid.cell_size[0], grid.cell_size[1]
    cmin = jnp.minimum(cw, ch)
    rf = r_need_table.astype(jnp.float32) + 1.0
    slack = jnp.sqrt((rf * cw) ** 2 + (rf * ch) ** 2)
    r_safe = jnp.floor(slack / cmin).astype(jnp.int32) + 1
    ys, xs = jnp.meshgrid(
        jnp.arange(grid.gy, dtype=jnp.int32),
        jnp.arange(grid.gx, dtype=jnp.int32),
        indexing="ij",
    )
    return jnp.clip(jnp.maximum(r_safe, r_need_table), 0, cover_radius(grid, xs, ys))


def seam_segment_ids(grid: UniformGrid, cx, cy, level: int):
    """Morton quadrant id (``0 .. 4**level - 1``) of each home cell.

    ``level`` recursive quadrant splits of the (power-of-two ceiling of the)
    grid: the id is the Morton interleave of the top ``level`` bits of each
    cell axis, i.e. exactly ``morton_ids(cx, cy) >> 2*(nbits - level)``.
    Because those are the *most significant* bits of the full Morton id, the
    segment id is nondecreasing along any Morton-sorted cell order — a
    Morton-sorted query batch is already segment-contiguous, which is what
    :func:`seam_layout` relies on to split query blocks at seams.
    """
    if level <= 0:
        return jnp.zeros(jnp.shape(cx), jnp.int32)
    nbits = max((max(grid.gx, grid.gy) - 1).bit_length(), level)
    shift = nbits - level
    return morton_ids(cx >> shift, cy >> shift)


def seam_layout(seg_sorted, n_segments: int, block_q: int, n_slots: int):
    """Block layout that never straddles a Morton seam — gather/scatter maps.

    A Morton-contiguous block of ``block_q`` queries that straddles a
    top-level Z-order quadrant boundary has home cells on *both* sides of
    the grid's centre cross, so its candidate rectangle approaches full grid
    width and blows past any sane static capacity (the measured m=100K
    overflow in ROADMAP.md).  The fix: pad each seam segment up to a
    multiple of ``block_q`` so block boundaries coincide with segment
    boundaries.

    Args:
      seg_sorted: ``(n_tot,)`` int32 nondecreasing segment id per
        Morton-sorted query (from :func:`seam_segment_ids`).
      n_segments: static segment-id bound (``4**level``).
      n_slots: static output length; any value ``>= n_tot +
        n_segments * block_q`` (the worst-case padding) works.

    Returns ``(src, dest)``: ``src (n_slots,)`` gathers the sorted arrays
    into the split layout — slots past a segment's true count repeat the
    segment's *last* query (the ``pad_tail`` trick, kept local to the
    segment so pad blocks have one-cell rectangles), and slots past the last
    segment repeat the final query.  ``dest (n_tot,)`` is each sorted
    query's slot (``src[dest[i]] == i``), for mapping per-slot results back.
    """
    n_tot = seg_sorted.shape[0]
    zero = jnp.zeros((1,), jnp.int32)
    counts = jnp.zeros((n_segments,), jnp.int32).at[seg_sorted].add(1)
    starts = jnp.concatenate([zero, jnp.cumsum(counts)])
    padded = jnp.concatenate([zero, jnp.cumsum(-(-counts // block_q) * block_q)])
    d = jnp.arange(n_slots, dtype=jnp.int32)
    seg_of = jnp.clip(jnp.searchsorted(padded, d, side="right").astype(jnp.int32) - 1,
                      0, n_segments - 1)
    within = d - padded[seg_of]
    src = starts[seg_of] + jnp.minimum(within, jnp.maximum(counts[seg_of] - 1, 0))
    src = jnp.minimum(src, n_tot - 1)  # trailing slots (and empty tail segments)
    dest = padded[seg_sorted] + jnp.arange(n_tot, dtype=jnp.int32) - starts[seg_sorted]
    return src, dest


class CellAggregates(NamedTuple):
    """Per-cell far-field aggregates over a grid's point set (plan-time).

    One entry per real cell (``n_cells``): the point count, the z-sum, the
    centroid of the cell's points, and the cell's integer grid coordinates.
    ``e_max`` is the grid-wide maximum distance from any point to its cell's
    centroid — the dispersion radius the far-field error model is built on
    (``engine.plan._choose_farfield_radius``): every point of a far cell
    lies within ``e_max`` of the centroid its aggregate term stands in for.

    ``z_dev_max`` (max within-cell deviation from the cell's z mean) and
    ``z_abs_max`` complete the error model's plan-time inputs: the far
    z-sum term pays a first-order (in dispersion) error proportional to how
    much z varies *inside* a cell, while the count term is second-order.

    Empty cells get their *geometric* centre as centroid (count and z-sum
    are 0, so the value never matters — but a finite coordinate keeps the
    far kernel's weight finite instead of manufacturing inf·0).
    """

    cent_x: jnp.ndarray  # (n_cells,) centroid x (cell centre when empty)
    cent_y: jnp.ndarray  # (n_cells,)
    count: jnp.ndarray   # (n_cells,) point count, data dtype (kernel operand)
    z_sum: jnp.ndarray   # (n_cells,) sum of z over the cell's points
    ix: jnp.ndarray      # (n_cells,) int32 cell x index
    iy: jnp.ndarray      # (n_cells,) int32 cell y index
    e_max: float         # max point-to-centroid distance over all cells
    z_dev_max: float     # max |z_j - cell z mean| over all cells
    z_abs_max: float     # max |z_j| over all points


def cell_aggregates(grid: UniformGrid) -> CellAggregates:
    """Compute :class:`CellAggregates` from the padded cell layout.

    Eager-only by convention (plan time, like :func:`build_grid`): ``e_max``
    is returned as a concrete float because the far-field radius choice
    needs it as a Python number.
    """
    nc = grid.n_cells
    dtype = grid.pt_x.dtype
    big = coord_sentinel(dtype)
    cx_cells = grid.cell_x[:nc]  # (nc, cap), pad slots hold the sentinel
    cy_cells = grid.cell_y[:nc]
    mask = cx_cells < big / 2
    cnt = grid.counts.reshape(-1).astype(dtype)
    denom = jnp.maximum(cnt, 1.0)
    sum_x = jnp.sum(jnp.where(mask, cx_cells, 0.0), axis=1)
    sum_y = jnp.sum(jnp.where(mask, cy_cells, 0.0), axis=1)
    ix = (jnp.arange(nc, dtype=jnp.int32) % grid.gx).astype(jnp.int32)
    iy = (jnp.arange(nc, dtype=jnp.int32) // grid.gx).astype(jnp.int32)
    centre_x = (grid.origin[0] + (ix.astype(dtype) + 0.5) * grid.cell_size[0]).astype(dtype)
    centre_y = (grid.origin[1] + (iy.astype(dtype) + 0.5) * grid.cell_size[1]).astype(dtype)
    cent_x = jnp.where(cnt > 0, sum_x / denom, centre_x)
    cent_y = jnp.where(cnt > 0, sum_y / denom, centre_y)
    z_sum = jnp.sum(grid.cell_z[:nc], axis=1)  # pad slots hold 0
    dev2 = jnp.where(
        mask,
        (cx_cells - cent_x[:, None]) ** 2 + (cy_cells - cent_y[:, None]) ** 2,
        0.0,
    )
    e_max = float(jnp.sqrt(jnp.max(dev2)))
    z_mean = z_sum / denom
    z_dev = jnp.where(mask, jnp.abs(grid.cell_z[:nc] - z_mean[:, None]), 0.0)
    z_dev_max = float(jnp.max(z_dev))
    z_abs_max = float(jnp.max(jnp.where(mask, jnp.abs(grid.cell_z[:nc]), 0.0)))
    return CellAggregates(cent_x, cent_y, cnt, z_sum, ix, iy, e_max,
                          z_dev_max, z_abs_max)


class QuadtreeLevel(NamedTuple):
    """One level of the far-field quadtree (plan-time, DESIGN.md §8).

    Level 0 is the grid's cells themselves; level ``l`` nodes cover
    ``2**l x 2**l`` cells (edge nodes cover the clipped remainder).  Each
    level is one flat padded array set of ``nx * ny`` nodes in row-major
    node order — no pointers, so the whole pyramid is a static-shape
    pytree the plan can carry.

    Per node: point ``count``, ``z_sum``, points centroid (``cent_x/y``,
    geometric node centre when empty), the FIRST z-moment about the
    centroid ``(mx, my) = sum_j z_j * (p_j - cent)`` (the dipole term that
    cancels the z budget's first-order error — DESIGN.md §8), ``e`` (an
    upper bound on the max point-to-centroid distance: exact at level 0,
    combined upward as ``max_children(|cent_child - cent| + e_child)``)
    and ``zd`` (same upward bound for the max |z_j - node z-mean|).

    ``e_max`` / ``zd_max`` are the level maxima as concrete floats — the
    plan's level-selection table is built from them.
    """

    nx: int              # nodes along x (= ceil(gx / 2**level))
    ny: int              # nodes along y
    step: int            # cells per node side (= 2**level)
    cent_x: jnp.ndarray  # (nx*ny,) points centroid (node centre when empty)
    cent_y: jnp.ndarray
    count: jnp.ndarray   # (nx*ny,) point count, data dtype (kernel operand)
    z_sum: jnp.ndarray   # (nx*ny,)
    mx: jnp.ndarray      # (nx*ny,) first z-moment about the centroid, x
    my: jnp.ndarray      # (nx*ny,) ... y
    e: jnp.ndarray       # (nx*ny,) per-node dispersion radius (upper bound)
    zd: jnp.ndarray      # (nx*ny,) per-node z-spread (upper bound)
    e_max: float         # max of e over the level's nonempty nodes
    zd_max: float        # max of zd over the level's nonempty nodes


def quadtree_level_count(gx: int, gy: int) -> int:
    """Static level count for :func:`quadtree_aggregates` — derived from the
    grid resolution alone: coarsen by 2x per level until at most 2 nodes
    remain per axis (a coarser root is never closeable: its opening gap
    would exceed the grid)."""
    levels = 1
    g = max(gx, gy)
    while (g + 1) // 2 > 2 and (1 << (levels - 1)) < g:
        g = (g + 1) // 2
        levels += 1
    return levels


def _node_centres(grid: UniformGrid, nx: int, ny: int, step: int, dtype):
    """Geometric centres of level nodes (used for empty nodes only)."""
    jx = jnp.arange(nx, dtype=jnp.int32)
    jy = jnp.arange(ny, dtype=jnp.int32)
    x_mid = 0.5 * (jx * step + jnp.minimum((jx + 1) * step, grid.gx)).astype(dtype)
    y_mid = 0.5 * (jy * step + jnp.minimum((jy + 1) * step, grid.gy)).astype(dtype)
    cx = (grid.origin[0] + x_mid * grid.cell_size[0]).astype(dtype)
    cy = (grid.origin[1] + y_mid * grid.cell_size[1]).astype(dtype)
    return (jnp.broadcast_to(cx[None, :], (ny, nx)),
            jnp.broadcast_to(cy[:, None], (ny, nx)))


def _pad_even(a, ny, nx, fill=0.0):
    """Pad a (ny, nx) level image to even dims with ``fill`` (empty nodes)."""
    return jnp.pad(a, ((0, ny % 2), (0, nx % 2)), constant_values=fill)


def quadtree_aggregates(grid: UniformGrid) -> tuple[QuadtreeLevel, ...]:
    """Bottom-up quadtree of far-field aggregates over the grid's points.

    Eager-only by convention (plan time, like :func:`cell_aggregates`):
    the per-level ``e_max`` / ``zd_max`` are concrete floats for the plan's
    level-selection table.  Level 0 is computed exactly from the padded
    cell layout; each coarser level combines 2x2 children with the exact
    reductions for count / z-sum / centroid / z-moment (the property the
    hypothesis re-aggregation test pins: a NumPy reduction of level ``l``
    reproduces level ``l+1`` bit for bit) and conservative upward bounds
    for the dispersion and z-spread radii:

        e_parent  = max over nonempty children of |cent_c - cent| + e_c
        zd_parent = max over nonempty children of |zbar_c - zbar| + zd_c

    The z-moment combination is exact because ``sum_{j in c} z_j (p_j -
    cent) = m_c + s_c (cent_c - cent)`` for each child c (``m_c`` its own
    moment, ``s_c`` its z-sum).
    """
    nc = grid.n_cells
    dtype = grid.pt_x.dtype
    big = coord_sentinel(dtype)
    agg = cell_aggregates(grid)
    cx_cells = grid.cell_x[:nc]
    cy_cells = grid.cell_y[:nc]
    mask = cx_cells < big / 2
    dev_x = jnp.where(mask, cx_cells - agg.cent_x[:, None], 0.0)
    dev_y = jnp.where(mask, cy_cells - agg.cent_y[:, None], 0.0)
    e0 = jnp.sqrt(jnp.max(dev_x * dev_x + dev_y * dev_y, axis=1))
    z_cells = grid.cell_z[:nc]
    mx0 = jnp.sum(jnp.where(mask, z_cells, 0.0) * dev_x, axis=1)
    my0 = jnp.sum(jnp.where(mask, z_cells, 0.0) * dev_y, axis=1)
    denom = jnp.maximum(agg.count, 1.0)
    zbar0 = agg.z_sum / denom
    zd0 = jnp.max(jnp.where(mask, jnp.abs(z_cells - zbar0[:, None]), 0.0), axis=1)

    n_levels = quadtree_level_count(grid.gx, grid.gy)
    levels = []
    nx, ny, step = grid.gx, grid.gy, 1
    cnt = agg.count.reshape(ny, nx)
    zs = agg.z_sum.reshape(ny, nx)
    ctx = agg.cent_x.reshape(ny, nx)
    cty = agg.cent_y.reshape(ny, nx)
    mx = mx0.reshape(ny, nx)
    my = my0.reshape(ny, nx)
    e = e0.reshape(ny, nx)
    zd = zd0.reshape(ny, nx)
    for level in range(n_levels):
        nonempty = cnt > 0
        e_max = float(jnp.max(jnp.where(nonempty, e, 0.0))) if nc else 0.0
        zd_max = float(jnp.max(jnp.where(nonempty, zd, 0.0))) if nc else 0.0
        levels.append(QuadtreeLevel(
            nx=nx, ny=ny, step=step,
            cent_x=ctx.reshape(-1), cent_y=cty.reshape(-1),
            count=cnt.reshape(-1), z_sum=zs.reshape(-1),
            mx=mx.reshape(-1), my=my.reshape(-1),
            e=e.reshape(-1), zd=zd.reshape(-1),
            e_max=e_max, zd_max=zd_max,
        ))
        if level == n_levels - 1:
            break
        children = [
            [_pad_even(a, ny, nx)[dy_::2, dx_::2] for a in
             (cnt, zs, ctx, cty, mx, my, e, zd)]
            for dy_, dx_ in ((0, 0), (0, 1), (1, 0), (1, 1))
        ]
        nx, ny, step = (nx + 1) // 2, (ny + 1) // 2, step * 2
        # exact reductions, fixed association order (the bitwise contract
        # of the re-aggregation test): c00 + c01 + c10 + c11
        cnt = ((children[0][0] + children[1][0]) + children[2][0]) + children[3][0]
        zs = ((children[0][1] + children[1][1]) + children[2][1]) + children[3][1]
        denom = jnp.maximum(cnt, 1.0)
        wsum_x = ((children[0][0] * children[0][2] + children[1][0] * children[1][2])
                  + children[2][0] * children[2][2]) + children[3][0] * children[3][2]
        wsum_y = ((children[0][0] * children[0][3] + children[1][0] * children[1][3])
                  + children[2][0] * children[2][3]) + children[3][0] * children[3][3]
        gx_mid, gy_mid = _node_centres(grid, nx, ny, step, dtype)
        ctx = jnp.where(cnt > 0, wsum_x / denom, gx_mid)
        cty = jnp.where(cnt > 0, wsum_y / denom, gy_mid)
        mx = sum(c[4] + c[1] * (c[2] - ctx) for c in children)
        my = sum(c[5] + c[1] * (c[3] - cty) for c in children)
        zbar = zs / denom
        e_terms = []
        zd_terms = []
        for c in children:
            dist = jnp.sqrt((c[2] - ctx) ** 2 + (c[3] - cty) ** 2)
            e_terms.append(jnp.where(c[0] > 0, dist + c[6], 0.0))
            czbar = c[1] / jnp.maximum(c[0], 1.0)
            zd_terms.append(jnp.where(c[0] > 0, jnp.abs(czbar - zbar) + c[7], 0.0))
        e = jnp.maximum(jnp.maximum(e_terms[0], e_terms[1]),
                        jnp.maximum(e_terms[2], e_terms[3]))
        zd = jnp.maximum(jnp.maximum(zd_terms[0], zd_terms[1]),
                         jnp.maximum(zd_terms[2], zd_terms[3]))
    return tuple(levels)


def morton_ids(cx, cy):
    """Morton (Z-order) interleave of cell indices — sorting queries by this
    keeps consecutive queries in spatially adjacent cells, so per-block
    candidate rectangles in the grid kernel stay compact (no row-major
    wrap-around blowup)."""

    def part1by1(v):
        v = v.astype(jnp.uint32)
        v = (v | (v << 8)) & jnp.uint32(0x00FF00FF)
        v = (v | (v << 4)) & jnp.uint32(0x0F0F0F0F)
        v = (v | (v << 2)) & jnp.uint32(0x33333333)
        v = (v | (v << 1)) & jnp.uint32(0x55555555)
        return v

    return (part1by1(cx) | (part1by1(cy) << 1)).astype(jnp.int32)
