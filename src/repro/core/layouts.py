"""Data layouts: SoA vs AoaS (paper §3.1.3, Fig. 2).

SoA  — three separate arrays ``x[m], y[m], z[m]``: lane-contiguous on TPU,
       minimal HBM bytes.
AoaS — one ``(m, 4)`` array of aligned structs ``(x, y, z, pad)``: the CUDA
       float4-alignment idea.  On TPU the analogous cost is 4/3x HBM traffic
       plus a lane-dimension of 4 (vs 128) unless re-tiled; the kernels
       consume it natively so the layout comparison is honest.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass
class PointSet:
    """A set of m attributed 2-D points in SoA form."""

    x: jnp.ndarray  # (m,)
    y: jnp.ndarray  # (m,)
    z: jnp.ndarray  # (m,)

    @property
    def m(self) -> int:
        return self.x.shape[0]

    def astype(self, dtype) -> "PointSet":
        return PointSet(self.x.astype(dtype), self.y.astype(dtype), self.z.astype(dtype))


def coord_sentinel(dtype):
    """Large-but-finite padding coordinate: its squared distance overflows to
    +inf, so a pad point carries weight ``exp(-a*inf) = 0`` and can never
    enter a k-best set.  The single definition behind every padded layout
    (kernel streams, grid cells, plan data) — see DESIGN.md §6."""
    return jnp.asarray(jnp.finfo(dtype).max / 4, dtype)


def pad_to(x, mult: int, value):
    """Pad a 1-D array to the next multiple of ``mult`` with ``value``.
    Static given ``x.shape`` — safe under jit."""
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), value, x.dtype)])


def pad_tail(x, n_pad: int):
    """Pad a 1-D array by repeating its last element.  Used for query blocks
    (a repeated query adds no new candidate cells to a block rectangle)."""
    if n_pad == 0:
        return x
    return jnp.concatenate([x, jnp.broadcast_to(x[-1], (n_pad,))])


def soa_to_aoas(x, y, z=None):
    """Pack SoA arrays into an (m, 4) aligned-struct array (x, y, z, 0)."""
    m = x.shape[0]
    cols = [x, y, z if z is not None else jnp.zeros((m,), x.dtype), jnp.zeros((m,), x.dtype)]
    return jnp.stack(cols, axis=1)


def aoas_to_soa(a):
    """Unpack an (m, 4) aligned-struct array into (x, y, z)."""
    return a[:, 0], a[:, 1], a[:, 2]
