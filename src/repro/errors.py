"""Typed warnings and errors for the repro package.

One class per failure mode, so callers and tests select on *type* instead of
substring-matching message text (the pre-PR-9 pattern: ``pytest.warns(...,
match="not provable")`` breaks on any rewording).  Every warning keeps the
stdlib category it historically used as a second base (``UserWarning`` for
plan-time honesty warnings, ``RuntimeWarning`` for serving-time degradation),
so existing ``warnings.simplefilter`` configurations and ``pytest.warns``
assertions against the stdlib categories keep working.

Hierarchy::

    ReproWarning
    ├── UnprovableRtolWarning      (UserWarning)     plan: requested farfield_rtol
    │                                                not provable at a profitable
    │                                                radius; honest bound reported
    ├── PathologicalGridWarning    (UserWarning)     plan: grid resolution leaves
    │                                                candidate rows near a full sweep
    ├── CapacityOverflowWarning    (RuntimeWarning)  execute: overflow_queries > 0
    │                                                persisted for the streak
    │                                                threshold — capacity undersized
    └── PlanDegradedWarning        (RuntimeWarning)  serving: the capacity
                                                     re-estimator gave up (build
                                                     failures / capacity cap);
                                                     results stay exact via the
                                                     ring-search / masked-exact
                                                     blend arms, at blend-arm cost
"""

from __future__ import annotations


class ReproWarning(Warning):
    """Base class for every warning the repro package emits on purpose."""


class UnprovableRtolWarning(ReproWarning, UserWarning):
    """The requested ``farfield_rtol`` is not provable at a profitable
    near-field radius; the plan ships the honest (larger) worst-case bound."""


class PathologicalGridWarning(ReproWarning, UserWarning):
    """The grid resolution is pathological for the data: some cell's safe
    ring radius is so large that candidate rows approach a full sweep."""


class CapacityOverflowWarning(ReproWarning, RuntimeWarning):
    """``overflow_queries > 0`` persisted for the streak threshold against
    one plan: the static candidate capacity looks undersized for the serving
    workload (results stay exact via the blend, at ring-search cost)."""


class PlanDegradedWarning(ReproWarning, RuntimeWarning):
    """The capacity re-estimator exhausted its retries or its capacity cap
    and stopped re-planning; serving continues on the installed plan, exact
    through the ring-search / masked-exact blend arms."""


class PlanBuildError(RuntimeError):
    """A background re-plan failed terminally (carried as the cause on the
    re-estimator's degrade event; never raised into the serving thread)."""
