"""Beyond-paper hillclimb #2 (EXPERIMENTS §Perf-AIDW): threshold-skip kNN.

Napkin math (v5e, k=10, bm=512): the baseline tiled kernel's vectorised
k-pass merge costs ~3k = 30 flop/pair — 58% of the kNN pass.  But once the
running k-best has seen t >> k*bm points, the probability a NEW TILE contains
any top-k candidate is ~bm*k/t; summed over tiles that is ~k*ln(m/(k*bm))
merging tiles out of m/bm — ~3% for m = 1M.  So: keep the k-best SORTED, test
the tile against the per-row threshold tau = kth-best (1 cmp/pair), and run
the merge under a ``pl.when(any-candidate)`` guard at query-block
granularity (branch-free per lane, one scalar branch per tile — exactly what
the TPU can do cheaply, unlike the CUDA per-thread early-out which diverges).

Expected kNN-pass cost: 7 + 1 + p_merge * 3k ~ 9 flop/pair vs 37 baseline.
The kernel also emits a per-block merge counter so interpret-mode runs can
MEASURE p_merge (reported in §Perf, benchmarks/fig_speedups path).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.aidw import AIDWParams
from repro.kernels._common import (
    alpha_from_best,
    merge_k_best,
    sq_dist_tile,
    tpu_compiler_params,
)

_SEMANTICS = tpu_compiler_params(("parallel", "arbitrary"))


def _knn_kernel_v2(qx_ref, qy_ref, dx_ref, dy_ref, alpha_ref, nmerge_ref, best, *, m_real, area, params):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best[...] = jnp.full(best.shape, jnp.inf, best.dtype)
        nmerge_ref[...] = jnp.zeros(nmerge_ref.shape, nmerge_ref.dtype)

    d2 = sq_dist_tile(qx_ref[...], qy_ref[...], dx_ref[...], dy_ref[...])  # (bn, bm)
    tau = best[:, -1:]  # kth best per row (best kept ascending by merge)
    has_candidate = jnp.any(d2 < tau)

    @pl.when(has_candidate)
    def _merge():
        best[...] = merge_k_best(best[...], d2, data_axis=1)
        nmerge_ref[...] += 1

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        alpha_ref[...] = alpha_from_best(best[...], m_real, area, params, data_axis=1)


def aidw_knn_v2(
    dx, dy, qx, qy, *, params: AIDWParams, area: float, m_real: int,
    block_q: int = 256, block_d: int = 512, interpret: bool = False,
):
    """Threshold-skip kNN pass.  Inputs pre-padded like aidw_tiled_soa.
    Returns (alpha (n,1), merges_per_block (n_blocks, 1) int32)."""
    n, m = qx.shape[0], dx.shape[1]
    dtype = qx.dtype
    grid = (n // block_q, m // block_d)
    k = params.k
    q_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    d_spec = pl.BlockSpec((1, block_d), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    c_spec = pl.BlockSpec((1, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_knn_kernel_v2, m_real=m_real, area=area, params=params),
        grid=grid,
        in_specs=[q_spec, q_spec, d_spec, d_spec],
        out_specs=[o_spec, c_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n, 1), dtype),
            jax.ShapeDtypeStruct((n // block_q, 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((block_q, k), dtype)],
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx, qy, dx, dy)


def aidw_tiled_v2_soa(
    dx, dy, dz, qx, qy, *, params: AIDWParams, area: float, m_real: int,
    block_q: int = 256, block_d: int = 512, interpret: bool = False,
):
    """Full v2 AIDW: threshold-skip kNN pass + the baseline weight pass.
    Returns (z_hat (n,1), alpha (n,1), merges (n_blocks,1))."""
    from repro.kernels.aidw_tiled import _weight_kernel_soa

    n, m = qx.shape[0], dx.shape[1]
    dtype = qx.dtype
    grid = (n // block_q, m // block_d)
    alpha, merges = aidw_knn_v2(
        dx, dy, qx, qy, params=params, area=area, m_real=m_real,
        block_q=block_q, block_d=block_d, interpret=interpret,
    )
    q_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    d_spec = pl.BlockSpec((1, block_d), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    zhat = pl.pallas_call(
        functools.partial(_weight_kernel_soa, eps=params.exact_hit_eps),
        grid=grid,
        in_specs=[q_spec, q_spec, q_spec, d_spec, d_spec, d_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, 1), dtype),
        scratch_shapes=[pltpu.VMEM((block_q, 1), dtype) for _ in range(4)],
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx, qy, alpha * 0.5, dx, dy, dz)
    return zhat, alpha, merges
