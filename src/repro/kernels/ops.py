"""Public entry points for the AIDW/IDW Pallas kernels.

Since the plan/execute refactor (DESIGN.md §6) these are thin conveniences
over ``repro.engine``: each call builds an :class:`InterpolationPlan`
(padding, sentinel data points, SoA/AoaS layout, interpret-mode
autodetection, the grid snapshot — all captured once, in one place) and
runs the jitted ``execute`` step.  Callers that interpolate more than one
query batch against the same dataset should hold the plan themselves:

    from repro.engine import build_plan, execute
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    z, a = execute(plan, qx, qy)          # compile once
    z2, a2 = execute(plan, qx2, qy2)      # cache hit
"""

from __future__ import annotations

import warnings
from typing import Literal

from repro.core.aidw import AIDWParams

Impl = Literal["naive", "tiled", "fused", "binned", "grid", "tiled_v2"]
Layout = Literal["soa", "aoas"]


def aidw(
    dx, dy, dz, qx, qy,
    *,
    params: AIDWParams = AIDWParams(),
    area: float,
    impl: Impl = "tiled",
    layout: Layout = "soa",
    block_q: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,
    grid=None,
):
    """AIDW via the Pallas kernels.  Returns ``(z_hat, alpha)``, shape (n,).

    ``impl``: "naive" (paper, no VMEM tiling), "tiled" (paper, shared-memory
    analogue), "binned" (approximate prefilter), "fused" (beyond-paper
    single-launch two-phase; SoA only), "grid" (static-shape spatial-partition
    Phase 1 — jit-compatible since the plan/execute refactor; ``grid=``
    accepts a prebuilt ``repro.core.grid.UniformGrid``), "tiled_v2"
    (threshold-skip kNN pass; use ``repro.engine.execute_with_stats`` for its
    merge-fraction diagnostic).
    ``layout``: "soa" | "aoas" — layout of the streamed data-point array.
    """
    from repro.engine import build_plan, execute  # lazy: kernels <-> engine

    if impl not in ("naive", "tiled", "fused", "binned", "grid", "tiled_v2"):
        # the engine also plans "idw"/"chunked"; those have their own entry
        # points (idw(), aidw_interpolate()) with different semantics
        raise ValueError(impl)
    plan = build_plan(
        dx, dy, dz,
        params=params, area=area, impl=impl, layout=layout,
        block_q=block_q, block_d=block_d, interpret=interpret, grid=grid,
    )
    return execute(plan, qx, qy)


def aidw_v2(
    dx, dy, dz, qx, qy,
    *,
    params: AIDWParams = AIDWParams(),
    area: float,
    block_q: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,
):
    """Deprecated standalone entry for the threshold-skip kernel; use
    ``aidw(..., impl="tiled_v2")`` (or the engine directly, which exposes the
    merge-fraction diagnostic via ``execute_with_stats``).

    Returns ``(z_hat, alpha, merge_fraction)`` — merge_fraction is the
    measured share of (query-block x data-tile) steps that actually ran the
    k-best merge.
    """
    warnings.warn(
        "aidw_v2 is deprecated; use aidw(..., impl='tiled_v2') or "
        "repro.engine.execute_with_stats for the merge-fraction diagnostic",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.engine import build_plan, execute_with_stats  # lazy: kernels <-> engine

    plan = build_plan(
        dx, dy, dz,
        params=params, area=area, impl="tiled_v2",
        block_q=block_q, block_d=block_d, interpret=interpret,
    )
    z, a, stats = execute_with_stats(plan, qx, qy)
    return z, a, stats["merge_fraction"]


def idw(
    dx, dy, dz, qx, qy,
    *,
    alpha: float = 2.0,
    block_q: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,
):
    """Standard IDW via the tiled Pallas kernel (SoA). Returns z_hat (n,)."""
    from repro.engine import build_plan, execute  # lazy: kernels <-> engine

    plan = build_plan(
        dx, dy, dz,
        impl="idw", idw_alpha=alpha,
        block_q=block_q, block_d=block_d, interpret=interpret,
    )
    z, _ = execute(plan, qx, qy)
    return z
