"""Public entry points for the AIDW/IDW Pallas kernels.

Since the plan/execute refactor (DESIGN.md §6) these are thin conveniences
over ``repro.engine``: each call builds an :class:`InterpolationPlan`
(padding, sentinel data points, SoA/AoaS layout, interpret-mode
autodetection, the grid snapshot — all captured once, in one place) and
runs the jitted ``execute`` step.  Repeated convenience calls against the
*same* data arrays reuse one memoized plan — since PR 9 the memo is the
process-default :class:`repro.serving.PlanRegistry` (bounded LRU, identity
guards, counters; ``_PLAN_CACHE``/``_plan_cache_counters`` remain as
read-only shims over it), so they stop paying the plan rebuild; callers
that interpolate many query batches should still hold the plan themselves
— it is explicit about lifetime and survives array identity changes:

    from repro.engine import build_plan, execute
    plan = build_plan(dx, dy, dz, params=p, area=1.0, impl="grid")
    z, a = execute(plan, qx, qy)          # compile once
    z2, a2 = execute(plan, qx2, qy2)      # cache hit
"""

from __future__ import annotations

import warnings
from typing import Literal

from repro.core.aidw import AIDWParams
from repro.serving.registry import default_registry, plan_key

Impl = Literal["naive", "tiled", "fused", "binned", "grid", "tiled_v2"]
Layout = Literal["soa", "aoas"]


def plan_cache_clear():
    """Drop all memoized convenience-API plans (test / memory-pressure hook).

    Since PR 9 this clears the process-default ``repro.serving``
    :class:`~repro.serving.PlanRegistry` (entries and counters), which is
    where the convenience memo lives.
    """
    default_registry().clear()


def _cached_build_plan(dx, dy, dz, **config):
    """Plan memoization for the one-shot conveniences, backed by the
    process-default serving registry: repeated aidw()/idw() calls against
    the same data arrays reuse one InterpolationPlan instead of paying the
    eager plan build (grid snapshot, required_radius table, capacity sweep)
    per call.  Keyed on the data arrays' ids + the static config; the
    registry's identity guards re-check the ids on every hit and evict the
    entry when a data array is collected (see ``serving/registry.py``).
    CAVEAT (documented on aidw/idw): identity-based memoization cannot see
    in-place mutation of a cached array's contents — mutate-and-
    reinterpolate callers must pass fresh arrays or call
    plan_cache_clear()."""
    from repro.engine import build_plan  # lazy: kernels <-> engine

    key = plan_key(dx, dy, dz, config)
    if key is None:  # unhashable config (e.g. a prebuilt grid=): no caching
        return build_plan(dx, dy, dz, **config)
    return default_registry().get_or_build(
        key, lambda: build_plan(dx, dy, dz, **config), guards=(dx, dy, dz)
    )


def __getattr__(name):
    # Back-compat shims over the serving registry for the PR-4 cache
    # internals: the entry dict (entries are (guards, plan) tuples, as
    # before) and the 2-key counter view.
    if name == "_PLAN_CACHE":
        return default_registry()._entries
    if name == "_plan_cache_counters":
        stats = default_registry().stats()
        return {"hits": stats["hits"], "misses": stats["misses"]}
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def aidw(
    dx, dy, dz, qx, qy,
    *,
    params: AIDWParams = AIDWParams(),
    area: float,
    impl: Impl = "tiled",
    layout: Layout = "soa",
    block_q: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,
    grid=None,
    phase2: str = "exact",
    farfield_rtol: float = 1e-3,
    farfield_radius: int | None = None,
):
    """AIDW via the Pallas kernels.  Returns ``(z_hat, alpha)``, shape (n,).

    ``impl``: "naive" (paper, no VMEM tiling), "tiled" (paper, shared-memory
    analogue), "binned" (approximate prefilter), "fused" (beyond-paper
    single-launch two-phase; SoA only), "grid" (static-shape spatial-partition
    Phase 1 — jit-compatible since the plan/execute refactor; ``grid=``
    accepts a prebuilt ``repro.core.grid.UniformGrid``), "tiled_v2"
    (threshold-skip kNN pass; use ``repro.engine.execute_with_stats`` for its
    merge-fraction diagnostic).
    ``layout``: "soa" | "aoas" — layout of the streamed data-point array.
    ``phase2``/``farfield_rtol``/``farfield_radius`` (impl="grid" only)
    select the far-field approximated Phase 2 with its plan-time error
    budget — see :func:`repro.engine.build_plan`.

    Repeat calls with the *same* ``dx/dy/dz`` array objects reuse a memoized
    plan (keyed on array identity, not contents): don't mutate data arrays
    in place between calls — pass fresh arrays, or call
    :func:`plan_cache_clear`.
    """
    from repro.engine import execute  # lazy: kernels <-> engine

    if impl not in ("naive", "tiled", "fused", "binned", "grid", "tiled_v2"):
        # the engine also plans "idw"/"chunked"; those have their own entry
        # points (idw(), aidw_interpolate()) with different semantics
        raise ValueError(impl)
    plan = _cached_build_plan(
        dx, dy, dz,
        params=params, area=area, impl=impl, layout=layout,
        block_q=block_q, block_d=block_d, interpret=interpret, grid=grid,
        phase2=phase2, farfield_rtol=farfield_rtol,
        farfield_radius=farfield_radius,
    )
    return execute(plan, qx, qy)


def aidw_v2(
    dx, dy, dz, qx, qy,
    *,
    params: AIDWParams = AIDWParams(),
    area: float,
    block_q: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,
):
    """Deprecated standalone entry for the threshold-skip kernel; use
    ``aidw(..., impl="tiled_v2")`` (or the engine directly, which exposes the
    merge-fraction diagnostic via ``execute_with_stats``).

    Returns ``(z_hat, alpha, merge_fraction)`` — merge_fraction is the
    measured share of (query-block x data-tile) steps that actually ran the
    k-best merge.
    """
    warnings.warn(
        "aidw_v2 is deprecated; use aidw(..., impl='tiled_v2') or "
        "repro.engine.execute_with_stats for the merge-fraction diagnostic",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.engine import execute_with_stats  # lazy: kernels <-> engine

    plan = _cached_build_plan(
        dx, dy, dz,
        params=params, area=area, impl="tiled_v2",
        block_q=block_q, block_d=block_d, interpret=interpret,
    )
    z, a, stats = execute_with_stats(plan, qx, qy)
    return z, a, stats["merge_fraction"]


def idw(
    dx, dy, dz, qx, qy,
    *,
    alpha: float = 2.0,
    block_q: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,
):
    """Standard IDW via the tiled Pallas kernel (SoA). Returns z_hat (n,).

    Plans are memoized on data-array identity (see :func:`aidw`): don't
    mutate ``dx/dy/dz`` in place between calls."""
    from repro.engine import execute  # lazy: kernels <-> engine

    plan = _cached_build_plan(
        dx, dy, dz,
        impl="idw", idw_alpha=alpha,
        block_q=block_q, block_d=block_d, interpret=interpret,
    )
    z, _ = execute(plan, qx, qy)
    return z
