"""Public, jit-compatible entry points for the AIDW/IDW Pallas kernels.

Handles: padding to block multiples (+inf sentinel data points carry zero
weight and never enter the k-best set), SoA/AoaS layout dispatch, orientation
reshapes, interpret-mode autodetection (interpret=True off-TPU so the same
call sites validate on CPU and deploy on TPU), and the paper's static
parameters (area A, m, k, alpha levels) baked in at trace time.
"""

from __future__ import annotations

import functools
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core.aidw import AIDWParams
from repro.core.layouts import soa_to_aoas
from repro.kernels.aidw_fused import aidw_fused_soa
from repro.kernels.aidw_naive import aidw_naive_aoas, aidw_naive_soa
from repro.kernels.aidw_tiled import aidw_tiled_aoas, aidw_tiled_soa
from repro.kernels.idw_tiled import idw_tiled_soa

Impl = Literal["naive", "tiled", "fused", "binned", "grid"]
Layout = Literal["soa", "aoas"]


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_to(x, mult, value):
    pad = (-x.shape[0]) % mult
    if pad == 0:
        return x
    return jnp.concatenate([x, jnp.full((pad,), value, x.dtype)])


def _sentinel(dtype):
    # large-but-finite coordinate: squared distance overflows to +inf in the
    # kernel, giving weight exp(-a*inf)=0 and never entering the k-best set.
    return jnp.asarray(jnp.finfo(dtype).max / 4, dtype)


def aidw(
    dx, dy, dz, qx, qy,
    *,
    params: AIDWParams = AIDWParams(),
    area: float,
    impl: Impl = "tiled",
    layout: Layout = "soa",
    block_q: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,
    grid=None,
):
    """AIDW via the Pallas kernels.  Returns ``(z_hat, alpha)``, shape (n,).

    ``impl``: "naive" (paper, no VMEM tiling), "tiled" (paper, shared-memory
    analogue), "binned" (approximate prefilter), "fused" (beyond-paper
    single-launch two-phase; SoA only), "grid" (spatial-partition Phase 1 —
    eager-only dispatch, see ``kernels.aidw_grid``; ``grid=`` accepts a
    prebuilt ``repro.core.grid.UniformGrid`` for reuse across query sets).
    ``layout``: "soa" | "aoas" — layout of the streamed data-point array.
    """
    if impl == "grid":
        from repro.kernels.aidw_grid import aidw_grid_soa

        if layout != "soa":
            raise ValueError("impl='grid' is SoA-only")
        m = dx.shape[0]
        if m < params.k:
            raise ValueError(f"need at least k={params.k} data points, got {m}")
        return aidw_grid_soa(
            dx, dy, dz, qx, qy,
            params=params, area=float(area), m_real=m, grid=grid,
            block_q=block_q, block_d=block_d, interpret=_auto_interpret(interpret),
        )
    if grid is not None:
        raise ValueError("grid= is only meaningful with impl='grid'")
    return _aidw_dense(
        dx, dy, dz, qx, qy,
        params=params, area=area, impl=impl, layout=layout,
        block_q=block_q, block_d=block_d, interpret=interpret,
    )


@functools.partial(
    jax.jit,
    static_argnames=("params", "area", "impl", "layout", "block_q", "block_d", "interpret"),
)
def _aidw_dense(
    dx, dy, dz, qx, qy,
    *,
    params: AIDWParams,
    area: float,
    impl: Impl,
    layout: Layout,
    block_q: int,
    block_d: int,
    interpret: bool | None,
):
    """The dense (full-sweep) kernel family behind :func:`aidw` — jitted;
    ``impl='grid'`` is dispatched eagerly above (its candidate shapes are
    occupancy-dependent and cannot be fixed under trace)."""
    interp = _auto_interpret(interpret)
    m, n = dx.shape[0], qx.shape[0]
    if m < params.k:
        raise ValueError(f"need at least k={params.k} data points, got {m}")
    dtype = qx.dtype
    big = _sentinel(dtype)

    if impl == "naive":
        block_q = min(block_q, 64)

    dxp = _pad_to(dx, block_d, big)
    dyp = _pad_to(dy, block_d, big)
    dzp = _pad_to(dz, block_d, jnp.zeros((), dtype))
    qxp = _pad_to(qx, block_q, jnp.zeros((), dtype))
    qyp = _pad_to(qy, block_q, jnp.zeros((), dtype))
    kw = dict(params=params, area=float(area), m_real=m, interpret=interp)

    if layout == "soa":
        dx2, dy2, dz2 = dxp[None, :], dyp[None, :], dzp[None, :]
        qx2, qy2 = qxp[:, None], qyp[:, None]
        if impl == "naive":
            z, a = aidw_naive_soa(dx2, dy2, dz2, qx2, qy2, block_q=block_q, **kw)
        elif impl == "tiled":
            z, a = aidw_tiled_soa(dx2, dy2, dz2, qx2, qy2, block_q=block_q, block_d=block_d, **kw)
        elif impl == "binned":
            # nbins: power-of-two divisor of block_d near 6k — keeps the
            # same-bin collision probability (the only error source) ~1% per
            # query on shuffled data; merge cost 3k(k+nbins)/block_d ~ 4
            # flop/pair vs 3k ~ 30 exact.
            nbins = 16
            while nbins * 2 <= min(6 * params.k, block_d // 4):
                nbins *= 2
            z, a = aidw_tiled_soa(
                dx2, dy2, dz2, qx2, qy2, block_q=block_q, block_d=block_d,
                nbins=nbins, **kw,
            )
        elif impl == "fused":
            z, a = aidw_fused_soa(dx2, dy2, dz2, qx2, qy2, block_q=block_q, block_d=block_d, **kw)
        else:
            raise ValueError(impl)
        return z[:n, 0], a[:n, 0]

    if layout == "aoas":
        data = soa_to_aoas(dxp, dyp, dzp)
        qx2, qy2 = qxp[None, :], qyp[None, :]
        if impl == "naive":
            z, a = aidw_naive_aoas(data, qx2, qy2, block_q=block_q, **kw)
        elif impl == "tiled":
            z, a = aidw_tiled_aoas(data, qx2, qy2, block_q=block_q, block_d=block_d, **kw)
        else:
            raise ValueError(f"impl={impl} not available for layout=aoas (fused is SoA-only)")
        return z[0, :n], a[0, :n]

    raise ValueError(layout)


@functools.partial(
    jax.jit,
    static_argnames=("params", "area", "block_q", "block_d", "interpret"),
)
def aidw_v2(
    dx, dy, dz, qx, qy,
    *,
    params: AIDWParams = AIDWParams(),
    area: float,
    block_q: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,
):
    """Threshold-skip AIDW (beyond-paper hillclimb, SoA).  Returns
    ``(z_hat, alpha, merge_fraction)`` — merge_fraction is the measured share
    of (query-block x data-tile) steps that actually ran the k-best merge."""
    from repro.kernels.aidw_tiled_v2 import aidw_tiled_v2_soa

    interp = _auto_interpret(interpret)
    m, n = dx.shape[0], qx.shape[0]
    if m < params.k:
        raise ValueError(f"need at least k={params.k} data points, got {m}")
    dtype = qx.dtype
    big = _sentinel(dtype)
    dxp = _pad_to(dx, block_d, big)[None, :]
    dyp = _pad_to(dy, block_d, big)[None, :]
    dzp = _pad_to(dz, block_d, jnp.zeros((), dtype))[None, :]
    qxp = _pad_to(qx, block_q, jnp.zeros((), dtype))[:, None]
    qyp = _pad_to(qy, block_q, jnp.zeros((), dtype))[:, None]
    z, a, merges = aidw_tiled_v2_soa(
        dxp, dyp, dzp, qxp, qyp, params=params, area=float(area), m_real=m,
        block_q=block_q, block_d=block_d, interpret=interp,
    )
    n_tiles = dxp.shape[1] // block_d
    frac = jnp.sum(merges).astype(jnp.float32) / (merges.shape[0] * n_tiles)
    return z[:n, 0], a[:n, 0], frac


@functools.partial(
    jax.jit, static_argnames=("alpha", "block_q", "block_d", "interpret")
)
def idw(
    dx, dy, dz, qx, qy,
    *,
    alpha: float = 2.0,
    block_q: int = 256,
    block_d: int = 512,
    interpret: bool | None = None,
):
    """Standard IDW via the tiled Pallas kernel (SoA). Returns z_hat (n,)."""
    interp = _auto_interpret(interpret)
    n = qx.shape[0]
    dtype = qx.dtype
    big = _sentinel(dtype)
    dxp = _pad_to(dx, block_d, big)[None, :]
    dyp = _pad_to(dy, block_d, big)[None, :]
    dzp = _pad_to(dz, block_d, jnp.zeros((), dtype))[None, :]
    qxp = _pad_to(qx, block_q, jnp.zeros((), dtype))[:, None]
    qyp = _pad_to(qy, block_q, jnp.zeros((), dtype))[:, None]
    z = idw_tiled_soa(
        dxp, dyp, dzp, qxp, qyp, alpha=alpha, block_q=block_q, block_d=block_d, interpret=interp
    )
    return z[:n, 0]
