"""Pallas TPU kernels for the paper's compute hot-spots.

The paper IS a kernel-engineering paper: its contribution is the naive and
tiled (shared-memory) AIDW kernels in two data layouts.  Each kernel here has
its pure-jnp oracle in ``ref.py`` and a jit'd public wrapper in ``ops.py``;
kernels are validated in interpret mode on CPU (TPU is the compile target).
"""

from repro.kernels.ops import aidw, idw

__all__ = ["aidw", "idw"]
