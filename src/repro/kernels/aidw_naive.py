"""Naive AIDW Pallas kernels — the paper's no-shared-memory version, TPU-native.

The CUDA naive kernel has every thread stream all m data-point coordinates
from *global memory*.  The closest faithful TPU analogue: the whole data
array is mapped into VMEM as a single (untiled) block that is re-materialised
for every query-block grid step, and — like the paper's kernel — the
distances are computed twice (kNN pass and weight pass) with no reuse.

TPU-honest consequence (see EXPERIMENTS §Perf): without tiling, the working
set is O(m + block_q * m), so the naive kernel stops being schedulable once
3*4*m + 4*block_q*(k+m) bytes approach the ~16 MiB of VMEM — around m≈300K
for block_q=8.  On the GPU the naive kernel merely got slower; on TPU the
untiled formulation hits a hard capacity wall.  This is the strongest
argument for the paper's tiling strategy on this hardware.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.aidw import AIDWParams
from repro.kernels._common import (
    alpha_from_best,
    merge_k_best,
    sq_dist_tile,
    tpu_compiler_params,
    weight_tile,
)

_SEMANTICS = tpu_compiler_params(("parallel",))


def _naive_kernel_soa(qx_ref, qy_ref, dx_ref, dy_ref, dz_ref, out_ref, alpha_ref, *, m_real, area, params):
    qx, qy = qx_ref[...], qy_ref[...]
    # --- pass 1: distances + kNN (paper Fig. 3 lines 11-34) ---
    d2 = sq_dist_tile(qx, qy, dx_ref[...], dy_ref[...])  # (bn, m)
    k = params.k
    best0 = jnp.full((qx.shape[0], k), jnp.inf, d2.dtype)
    best = merge_k_best(best0, d2, data_axis=1)
    alpha = alpha_from_best(best, m_real, area, params, data_axis=1)
    alpha_ref[...] = alpha
    # --- pass 2: distances AGAIN + weighting (paper lines 52-58) ---
    d2b = sq_dist_tile(qx, qy, dx_ref[...], dy_ref[...])
    sw, swz, tmin, thz = weight_tile(d2b, dz_ref[...], alpha * 0.5, data_axis=1)
    out_ref[...] = jnp.where(tmin <= params.exact_hit_eps, thz, swz / sw)


def _naive_kernel_aoas(qx_ref, qy_ref, d_ref, out_ref, alpha_ref, *, m_real, area, params):
    qx, qy = qx_ref[...], qy_ref[...]
    dxc, dyc, dzc = d_ref[:, 0:1], d_ref[:, 1:2], d_ref[:, 2:3]
    d2 = sq_dist_tile(qx, qy, dxc, dyc)  # (m, bn)
    k = params.k
    best0 = jnp.full((k, qx.shape[1]), jnp.inf, d2.dtype)
    best = merge_k_best(best0, d2, data_axis=0)
    alpha = alpha_from_best(best, m_real, area, params, data_axis=0)
    alpha_ref[...] = alpha
    d2b = sq_dist_tile(qx, qy, dxc, dyc)
    sw, swz, tmin, thz = weight_tile(d2b, dzc, alpha * 0.5, data_axis=0)
    out_ref[...] = jnp.where(tmin <= params.exact_hit_eps, thz, swz / sw)


def aidw_naive_soa(
    dx, dy, dz, qx, qy, *, params: AIDWParams, area: float, m_real: int,
    block_q: int = 64, interpret: bool = False,
):
    """Inputs pre-padded: qx/qy (n,1), dx/dy/dz (1,m). Returns (z_hat, alpha), (n,1) each."""
    n, m = qx.shape[0], dx.shape[1]
    dtype = qx.dtype
    grid = (n // block_q,)
    q_spec = pl.BlockSpec((block_q, 1), lambda i: (i, 0))
    d_spec = pl.BlockSpec((1, m), lambda i: (0, 0))  # full array, re-fetched per block
    o_spec = pl.BlockSpec((block_q, 1), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_naive_kernel_soa, m_real=m_real, area=area, params=params),
        grid=grid,
        in_specs=[q_spec, q_spec, d_spec, d_spec, d_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[jax.ShapeDtypeStruct((n, 1), dtype)] * 2,
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx, qy, dx, dy, dz)


def aidw_naive_aoas(
    data, qx, qy, *, params: AIDWParams, area: float, m_real: int,
    block_q: int = 64, interpret: bool = False,
):
    """Inputs pre-padded: data (m,4), qx/qy (1,n). Returns (z_hat, alpha), (1,n) each."""
    n, m = qx.shape[1], data.shape[0]
    dtype = qx.dtype
    grid = (n // block_q,)
    q_spec = pl.BlockSpec((1, block_q), lambda i: (0, i))
    d_spec = pl.BlockSpec((m, 4), lambda i: (0, 0))
    o_spec = pl.BlockSpec((1, block_q), lambda i: (0, i))
    return pl.pallas_call(
        functools.partial(_naive_kernel_aoas, m_real=m_real, area=area, params=params),
        grid=grid,
        in_specs=[q_spec, q_spec, d_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[jax.ShapeDtypeStruct((1, n), dtype)] * 2,
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx, qy, data)
