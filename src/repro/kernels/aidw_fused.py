"""Fused AIDW kernel — beyond-paper optimisation (EXPERIMENTS §Perf).

The paper launches two kernels (kNN pass, weight pass) and streams the data
points from HBM twice *and* re-reads the query block twice.  Here both phases
live in ONE ``pallas_call`` with grid ``(nq_blocks, 2, m_tiles)``: the middle
"phase" axis walks the data tiles twice while

  * the query block is fetched once per (i) and pinned in VMEM,
  * the per-query alpha produced by phase 0 is handed to phase 1 through VMEM
    scratch — it never round-trips to HBM,
  * one kernel launch instead of two (and no intermediate (n,1) alpha array
    written+read from HBM).

HBM traffic saved vs. tiled: n*4 B (alpha write) + n*4 B (alpha read)
+ one extra query sweep; data-point traffic is identical (2 sweeps — the
algorithm fundamentally needs alpha before weighting).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.aidw import AIDWParams
from repro.kernels._common import (
    alpha_from_best,
    merge_k_best,
    sq_dist_tile,
    tpu_compiler_params,
    weight_tile,
)

_SEMANTICS = tpu_compiler_params(("parallel", "arbitrary", "arbitrary"))


def _fused_kernel(
    qx_ref, qy_ref, dx_ref, dy_ref, dz_ref, out_ref, alpha_ref,
    best, ah, acc_w, acc_wz, min_d2, hit_z, *, m_real, area, params,
):
    phase = pl.program_id(1)
    j = pl.program_id(2)
    last_j = pl.num_programs(2) - 1
    qx, qy = qx_ref[...], qy_ref[...]
    d2 = sq_dist_tile(qx, qy, dx_ref[...], dy_ref[...])  # (bn, bm)

    @pl.when(phase == 0)
    def _knn_phase():
        @pl.when(j == 0)
        def _init():
            best[...] = jnp.full(best.shape, jnp.inf, best.dtype)

        best[...] = merge_k_best(best[...], d2, data_axis=1)

        @pl.when(j == last_j)
        def _finish():
            alpha = alpha_from_best(best[...], m_real, area, params, data_axis=1)
            alpha_ref[...] = alpha
            ah[...] = alpha * 0.5

    @pl.when(phase == 1)
    def _weight_phase():
        @pl.when(j == 0)
        def _init():
            acc_w[...] = jnp.zeros(acc_w.shape, acc_w.dtype)
            acc_wz[...] = jnp.zeros(acc_wz.shape, acc_wz.dtype)
            min_d2[...] = jnp.full(min_d2.shape, jnp.inf, min_d2.dtype)
            hit_z[...] = jnp.zeros(hit_z.shape, hit_z.dtype)

        sw, swz, tmin, thz = weight_tile(d2, dz_ref[...], ah[...], data_axis=1)
        acc_w[...] += sw
        acc_wz[...] += swz
        better = tmin < min_d2[...]
        hit_z[...] = jnp.where(better, thz, hit_z[...])
        min_d2[...] = jnp.where(better, tmin, min_d2[...])

        @pl.when(j == last_j)
        def _finish():
            out_ref[...] = jnp.where(
                min_d2[...] <= params.exact_hit_eps, hit_z[...], acc_wz[...] / acc_w[...]
            )


def aidw_fused_soa(
    dx, dy, dz, qx, qy, *, params: AIDWParams, area: float, m_real: int,
    block_q: int = 256, block_d: int = 512, interpret: bool = False,
):
    """Inputs pre-padded: qx/qy (n,1), dx/dy/dz (1,m). Returns (z_hat, alpha), (n,1) each."""
    n, m = qx.shape[0], dx.shape[1]
    dtype = qx.dtype
    grid = (n // block_q, 2, m // block_d)
    k = params.k
    q_spec = pl.BlockSpec((block_q, 1), lambda i, p, j: (i, 0))
    d_spec = pl.BlockSpec((1, block_d), lambda i, p, j: (0, j))
    o_spec = pl.BlockSpec((block_q, 1), lambda i, p, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_fused_kernel, m_real=m_real, area=area, params=params),
        grid=grid,
        in_specs=[q_spec, q_spec, d_spec, d_spec, d_spec],
        out_specs=[o_spec, o_spec],
        out_shape=[jax.ShapeDtypeStruct((n, 1), dtype)] * 2,
        scratch_shapes=[pltpu.VMEM((block_q, k), dtype)]
        + [pltpu.VMEM((block_q, 1), dtype) for _ in range(5)],
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx, qy, dx, dy, dz)
