"""Shared Pallas kernel-body helpers for the AIDW/IDW kernels.

Two in-kernel orientations (see DESIGN.md §2):

* ``data_axis=1`` (SoA family): queries vary along sublanes, data points along
  lanes — distance tile ``D`` is ``(bn, bm)``, per-query reductions run along
  axis 1.
* ``data_axis=0`` (AoaS family): the ``(bm, 4)`` aligned-struct tile puts data
  points on sublanes, so queries live on lanes — ``D`` is ``(bm, bn)`` and
  per-query reductions run along axis 0.

All helpers are pure jnp on values (not refs) so they lower identically in
Mosaic and in interpret mode, and can be unit-tested directly.
"""

from __future__ import annotations

import jax.numpy as jnp
from jax.experimental.pallas import tpu as pltpu

from repro.core.aidw import AIDWParams, adaptive_alpha


def tpu_compiler_params(dimension_semantics):
    """Version-portable ``compiler_params`` for TPU ``pallas_call``s.

    jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; which
    name exists depends on the installed jax (0.4.x ships only the old one).
    Every kernel module builds its dimension-semantics params through this
    shim so a rename breaks exactly one line, caught by the CI version matrix.
    """
    cls = getattr(pltpu, "CompilerParams", None)
    if cls is None:
        cls = pltpu.TPUCompilerParams
    return cls(dimension_semantics=tuple(dimension_semantics))


def sq_dist_tile(qx, qy, dx, dy):
    """Squared-distance tile via VPU broadcast (see DESIGN.md: beats the
    K=2 MXU matmul form at 1.6% MXU utilisation)."""
    ddx = qx - dx
    ddy = qy - dy
    return ddx * ddx + ddy * ddy


def merge_k_best(best, d2, data_axis: int):
    """Branch-free k-pass min-extract merge (duplicate-safe, argmin-free).

    best: (bn, k) for data_axis=1, (k, bn) for data_axis=0.
    d2:   distance tile with data points along ``data_axis``.
    Returns the k smallest per query, ascending along ``data_axis``.
    """
    ax = data_axis
    k = best.shape[ax]
    c = jnp.concatenate([best, d2], axis=ax)
    inf = jnp.asarray(jnp.inf, c.dtype)
    outs = []
    for _ in range(k):
        v = jnp.min(c, axis=ax, keepdims=True)
        outs.append(v)
        eq = (c == v).astype(jnp.int32)
        first = (jnp.cumsum(eq, axis=ax) == 1) & (eq == 1)
        c = jnp.where(first, inf, c)
    return jnp.concatenate(outs, axis=ax)


def alpha_from_best(best, m_real: int, area: float, params: AIDWParams, data_axis: int):
    """r_obs -> R(S0) -> mu -> alpha (Eq. 2-6), per query column/row.

    Returns alpha with keepdims (``(bn, 1)`` or ``(1, bn)``).
    """
    r_obs = jnp.mean(jnp.sqrt(best), axis=data_axis, keepdims=True)
    return adaptive_alpha(r_obs, m_real, area, params)


def pow_weight(d2, alpha_half):
    """The AIDW weight ``(d^2)^(-alpha/2) = d^(-alpha)`` from a squared
    distance, with the dtype-dependent tiny clamp (exact hits are handled by
    the callers' min-d² guard; sentinel distances overflow to +inf and yield
    weight 0).  The ONE kernel-side definition — the far-field aggregate arm
    must weigh centroids exactly as the near/full sweeps weigh points, or
    the proved error budget silently breaks."""
    dtype = d2.dtype
    tiny = jnp.asarray(1e-30 if dtype == jnp.float32 else 1e-290, dtype)
    return jnp.exp(-alpha_half * jnp.log(jnp.maximum(d2, tiny)))


def weight_tile(d2, dz, alpha_half, data_axis: int):
    """One tile of the weighting pass: returns (sum_w, sum_wz, tile_min, tile_hit_z),
    all keepdims along ``data_axis``.

    ``dz`` must broadcast against ``d2`` ( (1, bm) or (bm, 1) ), ``alpha_half``
    is the per-query half-power ((bn,1)/(1,bn)).
    """
    ax = data_axis
    w = pow_weight(d2, alpha_half)
    sum_w = jnp.sum(w, axis=ax, keepdims=True)
    sum_wz = jnp.sum(w * dz, axis=ax, keepdims=True)
    tile_min = jnp.min(d2, axis=ax, keepdims=True)
    eq = (d2 == tile_min).astype(jnp.int32)
    first = (jnp.cumsum(eq, axis=ax) == 1) & (eq == 1)
    zeros = jnp.zeros_like(w)
    tile_hit_z = jnp.sum(jnp.where(first, dz + zeros, zeros), axis=ax, keepdims=True)
    return sum_w, sum_wz, tile_min, tile_hit_z
