"""Standard-IDW tiled Pallas kernel — the paper's §5.3.1 comparison baseline.

One distance sweep (constant alpha, no kNN pass): half the data traffic and
roughly half the FLOPs of AIDW, quantified in benchmarks/fig_speedups.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._common import sq_dist_tile, tpu_compiler_params, weight_tile

_SEMANTICS = tpu_compiler_params(("parallel", "arbitrary"))


def _idw_kernel(qx_ref, qy_ref, dx_ref, dy_ref, dz_ref, out_ref, acc_w, acc_wz, min_d2, hit_z, *, alpha_half, eps):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_w[...] = jnp.zeros(acc_w.shape, acc_w.dtype)
        acc_wz[...] = jnp.zeros(acc_wz.shape, acc_wz.dtype)
        min_d2[...] = jnp.full(min_d2.shape, jnp.inf, min_d2.dtype)
        hit_z[...] = jnp.zeros(hit_z.shape, hit_z.dtype)

    d2 = sq_dist_tile(qx_ref[...], qy_ref[...], dx_ref[...], dy_ref[...])
    ah = jnp.asarray(alpha_half, d2.dtype)
    sw, swz, tmin, thz = weight_tile(d2, dz_ref[...], ah, data_axis=1)
    acc_w[...] += sw
    acc_wz[...] += swz
    better = tmin < min_d2[...]
    hit_z[...] = jnp.where(better, thz, hit_z[...])
    min_d2[...] = jnp.where(better, tmin, min_d2[...])

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        out_ref[...] = jnp.where(min_d2[...] <= eps, hit_z[...], acc_wz[...] / acc_w[...])


def idw_tiled_soa(
    dx, dy, dz, qx, qy, *, alpha: float = 2.0, exact_hit_eps: float = 1e-18,
    block_q: int = 256, block_d: int = 512, interpret: bool = False,
):
    """Inputs pre-padded: qx/qy (n,1), dx/dy/dz (1,m). Returns z_hat (n,1)."""
    n, m = qx.shape[0], dx.shape[1]
    dtype = qx.dtype
    grid = (n // block_q, m // block_d)
    q_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    d_spec = pl.BlockSpec((1, block_d), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_idw_kernel, alpha_half=alpha * 0.5, eps=exact_hit_eps),
        grid=grid,
        in_specs=[q_spec, q_spec, d_spec, d_spec, d_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, 1), dtype),
        scratch_shapes=[pltpu.VMEM((block_q, 1), dtype) for _ in range(4)],
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx, qy, dx, dy, dz)
