"""Pure-jnp oracles for every kernel in this package.

These are intentionally memory-naive (full (n, m) distance matrix) — the
ground truth each Pallas kernel is asserted against across shape/dtype sweeps
in ``tests/kernels``.
"""

from __future__ import annotations

from repro.core.aidw import AIDWParams, aidw_reference
from repro.core.idw import idw_reference
from repro.core.layouts import aoas_to_soa


def aidw_ref(dx, dy, dz, qx, qy, params: AIDWParams, area: float):
    """Oracle for aidw_{naive,tiled,fused} (SoA). Returns (z_hat, alpha)."""
    return aidw_reference(dx, dy, dz, qx, qy, params, area=area)


def aidw_ref_aoas(data_aoas, qx, qy, params: AIDWParams, area: float):
    """Oracle for the AoaS kernels: unpacks the (m, 4) struct array first.

    Layout must not change the maths — the oracle is layout-independent.
    """
    dx, dy, dz = aoas_to_soa(data_aoas)
    return aidw_reference(dx, dy, dz, qx, qy, params, area=area)


def idw_ref(dx, dy, dz, qx, qy, alpha: float):
    """Oracle for idw_tiled. Returns z_hat."""
    return idw_reference(dx, dy, dz, qx, qy, alpha)
