"""Tiled AIDW Pallas kernels — the paper's shared-memory version, TPU-native.

The CUDA tiled kernel stages blockDim-sized tiles of data-point coordinates
through shared memory.  Here the data-point axis is the *inner grid
dimension* of a ``pallas_call``: Pallas pipelines each ``(1, bm)`` (SoA) or
``(bm, 4)`` (AoaS) tile HBM→VMEM (double-buffered), while the query block
stays pinned in VMEM across the inner loop — the explicit TPU analogue of
"coordinates in shared memory, reused by every thread in the block".

Two kernels, matching the paper's two distance sweeps:
  1. knn pass  → per-query adaptive alpha (Eq. 2-6), running k-best in VMEM
     scratch (the vectorised replacement for the per-thread insertion sort).
  2. weight pass → accumulates Σw, Σw·z in VMEM scratch; exact-hit guard via
     running (min d², z_at_min).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.aidw import AIDWParams
from repro.kernels._common import (
    alpha_from_best,
    merge_k_best,
    sq_dist_tile,
    tpu_compiler_params,
    weight_tile,
)

_SEMANTICS = tpu_compiler_params(("parallel", "arbitrary"))


# ---------------------------------------------------------------- SoA family
def _knn_kernel_soa(qx_ref, qy_ref, dx_ref, dy_ref, alpha_ref, best, *, m_real, area, params, nbins=0):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best[...] = jnp.full(best.shape, jnp.inf, best.dtype)

    d2 = sq_dist_tile(qx_ref[...], qy_ref[...], dx_ref[...], dy_ref[...])  # (bn, bm)
    if nbins:
        # beyond-paper "binned" prefilter (§Perf-AIDW iteration 3): reduce the
        # tile to nbins contiguous bin-minima (1 op/pair) before the k-pass
        # merge — cuts merge cost ~bm/nbins-fold; mildly approximate (drops a
        # true neighbour only when two of a query's top-k land in the SAME
        # bin of the SAME tile; r_obs feeds a smooth map, error measured in
        # tests/benchmarks).
        bm = d2.shape[1]
        sub = bm // nbins
        cands = jnp.concatenate(
            [jnp.min(d2[:, i * sub : (i + 1) * sub], axis=1, keepdims=True) for i in range(nbins)],
            axis=1,
        )
    else:
        cands = d2
    best[...] = merge_k_best(best[...], cands, data_axis=1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        alpha_ref[...] = alpha_from_best(best[...], m_real, area, params, data_axis=1)


def _weight_kernel_soa(
    qx_ref, qy_ref, ah_ref, dx_ref, dy_ref, dz_ref, out_ref, acc_w, acc_wz, min_d2, hit_z, *, eps
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_w[...] = jnp.zeros(acc_w.shape, acc_w.dtype)
        acc_wz[...] = jnp.zeros(acc_wz.shape, acc_wz.dtype)
        min_d2[...] = jnp.full(min_d2.shape, jnp.inf, min_d2.dtype)
        hit_z[...] = jnp.zeros(hit_z.shape, hit_z.dtype)

    d2 = sq_dist_tile(qx_ref[...], qy_ref[...], dx_ref[...], dy_ref[...])
    sw, swz, tmin, thz = weight_tile(d2, dz_ref[...], ah_ref[...], data_axis=1)
    acc_w[...] += sw
    acc_wz[...] += swz
    better = tmin < min_d2[...]
    hit_z[...] = jnp.where(better, thz, hit_z[...])
    min_d2[...] = jnp.where(better, tmin, min_d2[...])

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        out_ref[...] = jnp.where(min_d2[...] <= eps, hit_z[...], acc_wz[...] / acc_w[...])


def aidw_tiled_soa(
    dx, dy, dz, qx, qy, *, params: AIDWParams, area: float, m_real: int,
    block_q: int = 256, block_d: int = 512, interpret: bool = False, nbins: int = 0,
):
    """Run both tiled passes. Inputs pre-padded: qx/qy (n,1), dx/dy/dz (1,m),
    n % block_q == 0, m % block_d == 0. Returns (z_hat (n,1), alpha (n,1)).
    nbins > 0 enables the approximate binned-prefilter kNN pass."""
    n = qx.shape[0]
    m = dx.shape[1]
    dtype = qx.dtype
    grid = (n // block_q, m // block_d)
    k = params.k

    q_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    d_spec = pl.BlockSpec((1, block_d), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))

    alpha = pl.pallas_call(
        functools.partial(_knn_kernel_soa, m_real=m_real, area=area, params=params, nbins=nbins),
        grid=grid,
        in_specs=[q_spec, q_spec, d_spec, d_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, 1), dtype),
        scratch_shapes=[pltpu.VMEM((block_q, k), dtype)],
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx, qy, dx, dy)

    zhat = pl.pallas_call(
        functools.partial(_weight_kernel_soa, eps=params.exact_hit_eps),
        grid=grid,
        in_specs=[q_spec, q_spec, q_spec, d_spec, d_spec, d_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n, 1), dtype),
        scratch_shapes=[pltpu.VMEM((block_q, 1), dtype) for _ in range(4)],
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx, qy, alpha * 0.5, dx, dy, dz)
    return zhat, alpha


# -------------------------------------------------------------- AoaS family
def _knn_kernel_aoas(qx_ref, qy_ref, d_ref, alpha_ref, best, *, m_real, area, params):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best[...] = jnp.full(best.shape, jnp.inf, best.dtype)

    # (bm, 4) aligned structs: data points on sublanes -> D is (bm, bn)
    dxc = d_ref[:, 0:1]
    dyc = d_ref[:, 1:2]
    d2 = sq_dist_tile(qx_ref[...], qy_ref[...], dxc, dyc)  # (bm, bn)
    best[...] = merge_k_best(best[...], d2, data_axis=0)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        alpha_ref[...] = alpha_from_best(best[...], m_real, area, params, data_axis=0)


def _weight_kernel_aoas(qx_ref, qy_ref, ah_ref, d_ref, out_ref, acc_w, acc_wz, min_d2, hit_z, *, eps):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_w[...] = jnp.zeros(acc_w.shape, acc_w.dtype)
        acc_wz[...] = jnp.zeros(acc_wz.shape, acc_wz.dtype)
        min_d2[...] = jnp.full(min_d2.shape, jnp.inf, min_d2.dtype)
        hit_z[...] = jnp.zeros(hit_z.shape, hit_z.dtype)

    dxc = d_ref[:, 0:1]
    dyc = d_ref[:, 1:2]
    dzc = d_ref[:, 2:3]
    d2 = sq_dist_tile(qx_ref[...], qy_ref[...], dxc, dyc)  # (bm, bn)
    sw, swz, tmin, thz = weight_tile(d2, dzc, ah_ref[...], data_axis=0)
    acc_w[...] += sw
    acc_wz[...] += swz
    better = tmin < min_d2[...]
    hit_z[...] = jnp.where(better, thz, hit_z[...])
    min_d2[...] = jnp.where(better, tmin, min_d2[...])

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        out_ref[...] = jnp.where(min_d2[...] <= eps, hit_z[...], acc_wz[...] / acc_w[...])


def aidw_tiled_aoas(
    data, qx, qy, *, params: AIDWParams, area: float, m_real: int,
    block_q: int = 256, block_d: int = 512, interpret: bool = False,
):
    """AoaS twin. Inputs pre-padded: data (m, 4) structs, qx/qy (1, n).
    Returns (z_hat (1, n), alpha (1, n))."""
    n = qx.shape[1]
    m = data.shape[0]
    dtype = qx.dtype
    grid = (n // block_q, m // block_d)
    k = params.k

    q_spec = pl.BlockSpec((1, block_q), lambda i, j: (0, i))
    d_spec = pl.BlockSpec((block_d, 4), lambda i, j: (j, 0))
    o_spec = pl.BlockSpec((1, block_q), lambda i, j: (0, i))

    alpha = pl.pallas_call(
        functools.partial(_knn_kernel_aoas, m_real=m_real, area=area, params=params),
        grid=grid,
        in_specs=[q_spec, q_spec, d_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((1, n), dtype),
        scratch_shapes=[pltpu.VMEM((k, block_q), dtype)],
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx, qy, data)

    zhat = pl.pallas_call(
        functools.partial(_weight_kernel_aoas, eps=params.exact_hit_eps),
        grid=grid,
        in_specs=[q_spec, q_spec, q_spec, d_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((1, n), dtype),
        scratch_shapes=[pltpu.VMEM((1, block_q), dtype) for _ in range(4)],
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx, qy, alpha * 0.5, data)
    return zhat, alpha
