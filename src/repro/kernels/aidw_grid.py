"""Grid-accelerated AIDW — static-shape execute machinery over the plan's
CSR grid snapshot.

The PR-1 version of this module materialised per-block *ragged* candidate
rows eagerly in Python (their width was a measured ``max`` over blocks), so
``impl="grid"`` could not be traced, vmapped, or donated.  The plan/execute
engine (``repro.engine``, DESIGN.md §6) fixes the candidate capacity ONCE at
plan time from the occupancy histogram; everything here is a pure function
of ``(snapshot arrays, queries, static capacity)`` and runs under ``jax.jit``:

* :func:`block_rectangles` — per-block candidate rectangles (cell coords)
  for Morton-contiguous query blocks, from the per-query safe radii.
* :func:`gather_candidates_csr` — the traced gather: each rectangle row
  ``(y, xlo..xhi)`` is one contiguous run of the grid's CSR point arrays, so
  a block's candidates are ``ht`` contiguous runs decoded into a STATIC
  ``capacity``-wide row (sentinel-padded).  Returns the true per-block need
  so the engine can fall back to the exact ring search when the plan-time
  capacity is exceeded (far out-of-bbox queries, adversarial batches) —
  the static fast path never silently drops a neighbour.
* :func:`phase1_alpha_from_candidates` — Phase 1 (kNN → adaptive alpha) over
  the candidate rows.  Two interchangeable pipelines behind one signature:
  the **scalar-prefetch indexed** pipeline (default, ``num_tiles`` given)
  drives a ``pltpu.PrefetchScalarGridSpec`` whose candidate index map clamps
  each block's tile walk to its own non-sentinel tiles — a sparse block does
  ``ceil(need/block_d)`` real steps instead of ``capacity/block_d`` (the
  block-sparse / ragged-kernel idiom: clamped revisits cost no DMA, the
  merge is predicated off) — and the **dense** fallback (``num_tiles=None``)
  walks every tile with the same kernel body as the tiled version
  (``_knn_kernel_soa``).  Either way per-query work is O(|neighbourhood|)
  instead of O(m).
* :func:`phase2_weights_full` — Phase 2 unchanged: AIDW weights ALL m data
  points, so the full-data sweep (``_weight_kernel_soa``) is reused verbatim.

Morton sorting, seam splitting, padding, the per-block overflow blend and
the unsort live in ``repro.engine.execute``; this module is only the kernel
plumbing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.aidw import AIDWParams
from repro.core.grid import UniformGrid
from repro.kernels._common import alpha_from_best, merge_k_best, sq_dist_tile
from repro.kernels.aidw_tiled import _SEMANTICS, _knn_kernel_soa, _weight_kernel_soa


def block_rectangles(grid: UniformGrid, cx, cy, r_safe, block_q: int):
    """Candidate rectangles for Morton-contiguous query blocks.

    Args:
      cx, cy: (n_sorted,) clamped home cells, ``n_sorted % block_q == 0``.
      r_safe: (n_sorted,) per-query containment-safe ring radii.

    Returns ``(xlo, xhi, ylo, yhi)`` of shape ``(nb,)`` each — the inclusive
    cell bounds of every block's rectangle: the bounding box of the block's
    home cells expanded by the block-max safe radius, clipped to the grid.
    """
    nb = cx.shape[0] // block_q
    cxb = cx.reshape(nb, block_q)
    cyb = cy.reshape(nb, block_q)
    rb = r_safe.reshape(nb, block_q).max(axis=1)
    xlo = jnp.clip(cxb.min(axis=1) - rb, 0, grid.gx - 1)
    xhi = jnp.clip(cxb.max(axis=1) + rb, 0, grid.gx - 1)
    ylo = jnp.clip(cyb.min(axis=1) - rb, 0, grid.gy - 1)
    yhi = jnp.clip(cyb.max(axis=1) + rb, 0, grid.gy - 1)
    return xlo, xhi, ylo, yhi


def gather_candidates_csr(grid: UniformGrid, xlo, xhi, ylo, yhi, capacity: int):
    """Traced per-block candidate gather from the CSR snapshot, static width.

    Each rectangle row ``(y, xlo..xhi)`` maps to the contiguous CSR run
    ``pt_*[starts[y*gx + xlo] : starts[y*gx + xhi + 1]]``.  Slot ``s`` of a
    block's row indexes the concatenation of those runs: a batched
    ``searchsorted`` over the per-row prefix sums decodes ``s`` into
    ``(row, offset-within-row)``.  Slots past the block's true candidate
    count — and every slot past ``capacity`` when the block overflows — read
    the CSR sentinel (index ``m``), whose squared distance overflows to +inf.

    Returns ``(cand_x, cand_y, need)``: candidates ``(nb, capacity)`` and the
    true per-block candidate count ``need (nb,)``.  ``need > capacity`` means
    this gather is incomplete and the caller must use the exact fallback.
    """
    nb = xlo.shape[0]
    gx, gy = grid.gx, grid.gy
    rows = jnp.arange(gy, dtype=jnp.int32)[None, :]                 # (1, gy)
    ht = yhi - ylo + 1
    y = ylo[:, None] + rows                                          # (nb, gy)
    row_ok = rows < ht[:, None]
    ysafe = jnp.minimum(y, gy - 1)
    c = grid.cum
    x0 = xlo[:, None]
    x1 = xhi[:, None] + 1
    cnt = c[ysafe + 1, x1] - c[ysafe + 1, x0] - c[ysafe, x1] + c[ysafe, x0]
    cnt = jnp.where(row_ok, cnt, 0)
    offs = jnp.concatenate([jnp.zeros((nb, 1), jnp.int32), jnp.cumsum(cnt, axis=1)], axis=1)
    need = offs[:, -1]

    s = jnp.broadcast_to(jnp.arange(capacity, dtype=jnp.int32)[None, :], (nb, capacity))
    row = jax.vmap(functools.partial(jnp.searchsorted, side="right"))(offs, s) - 1
    row = jnp.clip(row, 0, gy - 1)
    within = s - jnp.take_along_axis(offs, row, axis=1)
    base_cid = (ylo[:, None] + row) * gx + x0
    idx = grid.starts[jnp.clip(base_cid, 0, grid.n_cells)] + within
    m = grid.n_points
    valid = s < jnp.minimum(need, capacity)[:, None]
    idx = jnp.where(valid, jnp.clip(idx, 0, m - 1), m)               # m = sentinel slot
    return grid.pt_x[idx], grid.pt_y[idx], need


def _knn_kernel_skip(nt_ref, qx_ref, qy_ref, dx_ref, dy_ref, alpha_ref, best,
                     *, m_real, area, params):
    """Sparsity-skipping twin of ``_knn_kernel_soa``.

    ``nt_ref`` is the scalar-prefetched per-block tile count: steps past it
    are clamped revisits of the block's last real tile (no DMA) and the
    k-best merge is predicated off, so an all-sentinel tail costs grid
    overhead only.  Init/finish still fire on the first/last *grid* step —
    the output block is written exactly once per query block.
    """
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best[...] = jnp.full(best.shape, jnp.inf, best.dtype)

    @pl.when(j < nt_ref[i])
    def _merge():
        d2 = sq_dist_tile(qx_ref[...], qy_ref[...], dx_ref[...], dy_ref[...])
        best[...] = merge_k_best(best[...], d2, data_axis=1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        alpha_ref[...] = alpha_from_best(best[...], m_real, area, params, data_axis=1)


def phase1_alpha_from_candidates(
    qx_s, qy_s, cand_x, cand_y, *,
    params: AIDWParams, area: float, m_real: int,
    block_q: int, block_d: int, interpret: bool,
    num_tiles=None,
):
    """Phase 1 over per-block candidate rows.

    qx_s/qy_s: (n_tot,) Morton-sorted padded queries, ``n_tot % block_q == 0``;
    cand_x/cand_y: (nb, c_tot) with ``c_tot % block_d == 0``.
    Returns alpha, shape ``(n_tot, 1)``.

    ``num_tiles`` (optional ``(nb,)`` int32, ``ceil(covered_need/block_d)``)
    selects the scalar-prefetch pipeline: block ``i``'s candidate index map
    becomes ``min(j, num_tiles[i]-1)`` so its all-sentinel tail tiles are
    never fetched and never merged — the per-block tile table the plan's
    launch-wide capacity cannot express.  ``None`` keeps the dense walk
    (every block streams all ``c_tot // block_d`` tiles); both pipelines
    merge identical non-sentinel candidates, so their alpha agrees exactly.
    """
    n_tot = qx_s.shape[0]
    nb, c_tot = cand_x.shape
    dtype = qx_s.dtype
    qx2, qy2 = qx_s[:, None], qy_s[:, None]
    out_shape = jax.ShapeDtypeStruct((n_tot, 1), dtype)
    scratch = [pltpu.VMEM((block_q, params.k), dtype)]

    if num_tiles is None:
        q_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
        c_spec = pl.BlockSpec((1, block_d), lambda i, j: (i, j))
        o_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
        return pl.pallas_call(
            functools.partial(_knn_kernel_soa, m_real=m_real, area=area, params=params),
            grid=(nb, c_tot // block_d),
            in_specs=[q_spec, q_spec, c_spec, c_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            scratch_shapes=scratch,
            compiler_params=_SEMANTICS,
            interpret=interpret,
        )(qx2, qy2, cand_x, cand_y)

    def q_map(i, j, nt):
        return (i, 0)

    def c_map(i, j, nt):
        # clamp past-need steps to the block's last real tile: Pallas skips
        # the DMA for a revisited block index, the kernel skips the merge
        return (i, jnp.maximum(jnp.minimum(j, nt[i] - 1), 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, c_tot // block_d),
        in_specs=[
            pl.BlockSpec((block_q, 1), q_map),
            pl.BlockSpec((block_q, 1), q_map),
            pl.BlockSpec((1, block_d), c_map),
            pl.BlockSpec((1, block_d), c_map),
        ],
        out_specs=pl.BlockSpec((block_q, 1), q_map),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        functools.partial(_knn_kernel_skip, m_real=m_real, area=area, params=params),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(num_tiles.astype(jnp.int32), qx2, qy2, cand_x, cand_y)


def phase2_weights_full(
    qx_s, qy_s, alpha, dxp, dyp, dzp, *,
    eps: float, block_q: int, block_d: int, interpret: bool,
):
    """Phase 2: full-data weighted sweep (AIDW weights ALL m points).

    dxp/dyp/dzp: (1, mp) sentinel-padded data, ``mp % block_d == 0``.
    Returns z_hat, shape ``(n_tot, 1)``.
    """
    n_tot = qx_s.shape[0]
    dtype = qx_s.dtype
    qx2, qy2 = qx_s[:, None], qy_s[:, None]
    q_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    d_spec = pl.BlockSpec((1, block_d), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_weight_kernel_soa, eps=eps),
        grid=(n_tot // block_q, dxp.shape[1] // block_d),
        in_specs=[q_spec, q_spec, q_spec, d_spec, d_spec, d_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n_tot, 1), dtype),
        scratch_shapes=[pltpu.VMEM((block_q, 1), dtype) for _ in range(4)],
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx2, qy2, alpha * 0.5, dxp, dyp, dzp)
