"""Grid-accelerated AIDW — Phase 1 streams candidate neighbourhoods only.

The tiled kernel's Phase 1 (kNN -> adaptive alpha) streams ALL m data points
past every query block; that brute-force sweep dominates runtime as m grows.
Here the host bucket-sorts the data points into a :class:`UniformGrid`
(``repro.core.grid``), sorts the queries into Morton order so each query
block lives in a compact patch of cells, and gathers one *candidate row* per
block: the padded points of every cell inside the block's safe rectangle
(per-query :func:`safe_radius`, maxed over the block, around the bounding
box of the block's home cells — guaranteed to contain each query's true k
nearest neighbours by occupancy alone, DESIGN.md §4).

Phase 1 then runs the *same* kernel body as the tiled version
(``_knn_kernel_soa`` — running k-best merge, alpha via Eq. 2-6), but the
inner grid dimension walks the block's candidate row instead of the full
data axis: per-query work drops from O(m) to O(|neighbourhood|), near O(k)
at the paper's densities.  Phase 2 is unchanged (AIDW weights ALL m points,
so the full-data sweep is reused verbatim via ``_weight_kernel_soa``) and
the outputs are unsorted back to caller order.

Host prep is eager-only: candidate-row width is occupancy-dependent
(``max`` over blocks), so ``impl="grid"`` cannot be called under an outer
``jit`` — build once, interpolate many.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.aidw import AIDWParams
from repro.core.grid import (
    UniformGrid,
    build_grid,
    cell_of,
    coord_sentinel,
    morton_ids,
    safe_radius,
)
from repro.kernels.aidw_tiled import _SEMANTICS, _knn_kernel_soa, _weight_kernel_soa


def _pad_tail(x, n_pad):
    """Pad a 1-D array by repeating its last element (keeps per-block cell
    rectangles unchanged — a repeated query adds no new candidate cells)."""
    if n_pad == 0:
        return x
    return jnp.concatenate([x, jnp.broadcast_to(x[-1], (n_pad,))])


def gather_block_candidates(grid: UniformGrid, cx, cy, r_safe, block_q: int):
    """Per-block candidate rows for Morton-contiguous query blocks.

    Args:
      cx, cy: (n_sorted,) clamped home cells, ``n_sorted % block_q == 0``.
      r_safe: (n_sorted,) per-query safe ring radii.

    Returns ``(cand_x, cand_y)`` of shape ``(nb, C)`` where ``C`` is the
    batch-max rectangle size in points (eager value); masked / out-of-rect
    slots hold the +inf-overflow sentinel.
    """
    nb = cx.shape[0] // block_q
    cxb = cx.reshape(nb, block_q)
    cyb = cy.reshape(nb, block_q)
    rb = r_safe.reshape(nb, block_q).max(axis=1)
    xlo = jnp.clip(cxb.min(axis=1) - rb, 0, grid.gx - 1)
    xhi = jnp.clip(cxb.max(axis=1) + rb, 0, grid.gx - 1)
    ylo = jnp.clip(cyb.min(axis=1) - rb, 0, grid.gy - 1)
    yhi = jnp.clip(cyb.max(axis=1) + rb, 0, grid.gy - 1)
    wd = xhi - xlo + 1
    ht = yhi - ylo + 1
    c_cells = int(jnp.max(wd * ht))  # eager: fixes the candidate-row width

    j = jnp.arange(c_cells, dtype=jnp.int32)[None, :]
    jx = j % wd[:, None]
    jy = j // wd[:, None]
    valid = jy < ht[:, None]
    ccx = xlo[:, None] + jx
    ccy = ylo[:, None] + jy
    cid = jnp.where(valid, ccy * grid.gx + ccx, grid.n_cells)  # sentinel row
    cand_x = grid.cell_x[cid].reshape(nb, c_cells * grid.cap)
    cand_y = grid.cell_y[cid].reshape(nb, c_cells * grid.cap)
    return cand_x, cand_y


def aidw_grid_soa(
    dx, dy, dz, qx, qy, *,
    params: AIDWParams, area: float, m_real: int,
    grid: UniformGrid | None = None,
    block_q: int = 256, block_d: int = 512, interpret: bool = False,
):
    """Two-phase grid AIDW.  Raw 1-D unpadded inputs; returns
    ``(z_hat, alpha)``, shape ``(n,)`` each, in caller query order.

    ``grid`` may be prebuilt (reuse across query batches); otherwise one is
    built from the data points at the default occupancy.
    """
    n = qx.shape[0]
    dtype = qx.dtype
    k = params.k
    if grid is None:
        grid = build_grid(dx, dy, dz)

    # ---- host prep (eager): Morton-sort queries, gather candidate rows ----
    cx, cy = cell_of(grid, qx, qy)
    order = jnp.argsort(morton_ids(cx, cy), stable=True)
    n_pad = (-n) % block_q
    qx_s = _pad_tail(qx[order], n_pad)
    qy_s = _pad_tail(qy[order], n_pad)
    cx_s, cy_s, r_safe = safe_radius(grid, qx_s, qy_s, k)
    cand_x, cand_y = gather_block_candidates(grid, cx_s, cy_s, r_safe, block_q)

    nb, c_width = cand_x.shape
    n_tot = nb * block_q
    bd = min(block_d, max(((c_width + 127) // 128) * 128, 128))
    c_pad = (-c_width) % bd
    if c_pad:
        big = coord_sentinel(dtype)
        pad = jnp.full((nb, c_pad), big, dtype)
        cand_x = jnp.concatenate([cand_x, pad], axis=1)
        cand_y = jnp.concatenate([cand_y, pad], axis=1)
    c_tot = c_width + c_pad

    # ---- phase 1: kNN/alpha over candidate rows (same body as tiled) ----
    qx2 = qx_s[:, None]
    qy2 = qy_s[:, None]
    q_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    c_spec = pl.BlockSpec((1, bd), lambda i, j: (i, j))
    o_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    alpha = pl.pallas_call(
        functools.partial(_knn_kernel_soa, m_real=m_real, area=area, params=params),
        grid=(nb, c_tot // bd),
        in_specs=[q_spec, q_spec, c_spec, c_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n_tot, 1), dtype),
        scratch_shapes=[pltpu.VMEM((block_q, k), dtype)],
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx2, qy2, cand_x, cand_y)

    # ---- phase 2: full-data weighted sweep (AIDW weights all m points) ----
    big = coord_sentinel(dtype)
    m_pad = (-m_real) % bd
    dxp = jnp.concatenate([dx, jnp.full((m_pad,), big, dtype)])[None, :]
    dyp = jnp.concatenate([dy, jnp.full((m_pad,), big, dtype)])[None, :]
    dzp = jnp.concatenate([dz, jnp.zeros((m_pad,), dtype)])[None, :]
    grid2 = (nb, dxp.shape[1] // bd)
    d_spec = pl.BlockSpec((1, bd), lambda i, j: (0, j))
    zhat = pl.pallas_call(
        functools.partial(_weight_kernel_soa, eps=params.exact_hit_eps),
        grid=grid2,
        in_specs=[q_spec, q_spec, q_spec, d_spec, d_spec, d_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n_tot, 1), dtype),
        scratch_shapes=[pltpu.VMEM((block_q, 1), dtype) for _ in range(4)],
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx2, qy2, alpha * 0.5, dxp, dyp, dzp)

    # ---- unsort back to caller order ----
    inv = jnp.argsort(order)
    return zhat[:n, 0][inv], alpha[:n, 0][inv]
