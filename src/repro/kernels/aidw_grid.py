"""Grid-accelerated AIDW — static-shape execute machinery over the plan's
CSR grid snapshot.

The PR-1 version of this module materialised per-block *ragged* candidate
rows eagerly in Python (their width was a measured ``max`` over blocks), so
``impl="grid"`` could not be traced, vmapped, or donated.  The plan/execute
engine (``repro.engine``, DESIGN.md §6) fixes the candidate capacity ONCE at
plan time from the occupancy histogram; everything here is a pure function
of ``(snapshot arrays, queries, static capacity)`` and runs under ``jax.jit``:

* :func:`block_rectangles` — per-block candidate rectangles (cell coords)
  for Morton-contiguous query blocks, from the per-query safe radii.
* :func:`gather_candidates_csr` — the traced gather: each rectangle row
  ``(y, xlo..xhi)`` is one contiguous run of the grid's CSR point arrays, so
  a block's candidates are ``ht`` contiguous runs decoded into a STATIC
  ``capacity``-wide row (sentinel-padded).  Returns the true per-block need
  so the engine can fall back to the exact ring search when the plan-time
  capacity is exceeded (far out-of-bbox queries, adversarial batches) —
  the static fast path never silently drops a neighbour.
* :func:`phase1_alpha_from_candidates` — Phase 1 (kNN → adaptive alpha) over
  the candidate rows.  Two interchangeable pipelines behind one signature:
  the **scalar-prefetch indexed** pipeline (default, ``num_tiles`` given)
  drives a ``pltpu.PrefetchScalarGridSpec`` whose candidate index map clamps
  each block's tile walk to its own non-sentinel tiles — a sparse block does
  ``ceil(need/block_d)`` real steps instead of ``capacity/block_d`` (the
  block-sparse / ragged-kernel idiom: clamped revisits cost no DMA, the
  merge is predicated off) — and the **dense** fallback (``num_tiles=None``)
  walks every tile with the same kernel body as the tiled version
  (``_knn_kernel_soa``).  Either way per-query work is O(|neighbourhood|)
  instead of O(m).
* :func:`phase2_weights_full` — exact Phase 2 (the default): AIDW weights
  ALL m data points, so the full-data sweep (``_weight_kernel_soa``) is
  reused verbatim.
* :func:`phase2_near_weights` + :func:`phase2_far_aggregates` — the
  far-field approximated Phase 2 (``build_plan(phase2="farfield")``,
  DESIGN.md §7).  The near kernel sweeps exact per-point weights over the
  block's near-rectangle candidate rows (same CSR gather, same
  scalar-prefetch tile table as Phase 1 — sparse blocks skip their
  all-sentinel tail tiles) and returns the four partial accumulators
  ``(sum_w, sum_wz, min_d2, hit_z)`` instead of a finished z.  The far
  kernel sweeps the plan's per-cell aggregates (count, z-sum, centroid)
  once per cell, masking cells inside the block's scalar-prefetched near
  rectangle (those are covered exactly), and folds ``count*w(centroid)`` /
  ``z_sum*w(centroid)`` into ``(sum_w, sum_wz)``.  The engine combines the
  two and applies the exact-hit guard; the worst-case relative error is
  bounded at plan time (``engine.plan._choose_farfield_radius``).
* :func:`phase2_far_nodes` — the multi-level quadtree far field
  (``build_plan(phase2="quadtree")``, DESIGN.md §8): the same near kernel,
  but the far sweep runs once per quadtree LEVEL over per-block tables of
  closed nodes (gathered by the engine's Barnes–Hut walk), each node
  contributing its aggregate term plus a dipole z-moment correction — the
  piece that cancels the z budget's first-order error and makes the plan's
  bound second-order in the opening ratio.

Morton sorting, seam splitting, padding, the per-block overflow blend, the
quadtree level walk and the unsort live in ``repro.engine.execute``; this
module is only the kernel plumbing.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.aidw import AIDWParams
from repro.core.grid import UniformGrid
from repro.kernels._common import (
    alpha_from_best,
    merge_k_best,
    pow_weight,
    sq_dist_tile,
    weight_tile,
)
from repro.kernels.aidw_tiled import _SEMANTICS, _knn_kernel_soa, _weight_kernel_soa


def block_rectangles(grid: UniformGrid, cx, cy, r_safe, block_q: int):
    """Candidate rectangles for Morton-contiguous query blocks.

    Args:
      cx, cy: (n_sorted,) clamped home cells, ``n_sorted % block_q == 0``.
      r_safe: (n_sorted,) per-query containment-safe ring radii.

    Returns ``(xlo, xhi, ylo, yhi)`` of shape ``(nb,)`` each — the inclusive
    cell bounds of every block's rectangle: the bounding box of the block's
    home cells expanded by the block-max safe radius, clipped to the grid.
    """
    nb = cx.shape[0] // block_q
    cxb = cx.reshape(nb, block_q)
    cyb = cy.reshape(nb, block_q)
    rb = r_safe.reshape(nb, block_q).max(axis=1)
    xlo = jnp.clip(cxb.min(axis=1) - rb, 0, grid.gx - 1)
    xhi = jnp.clip(cxb.max(axis=1) + rb, 0, grid.gx - 1)
    ylo = jnp.clip(cyb.min(axis=1) - rb, 0, grid.gy - 1)
    yhi = jnp.clip(cyb.max(axis=1) + rb, 0, grid.gy - 1)
    return xlo, xhi, ylo, yhi


def gather_candidates_csr(grid: UniformGrid, xlo, xhi, ylo, yhi, capacity: int,
                          with_z: bool = False):
    """Traced per-block candidate gather from the CSR snapshot, static width.

    Each rectangle row ``(y, xlo..xhi)`` maps to the contiguous CSR run
    ``pt_*[starts[y*gx + xlo] : starts[y*gx + xhi + 1]]``.  Slot ``s`` of a
    block's row indexes the concatenation of those runs: a batched
    ``searchsorted`` over the per-row prefix sums decodes ``s`` into
    ``(row, offset-within-row)``.  Slots past the block's true candidate
    count — and every slot past ``capacity`` when the block overflows — read
    the CSR sentinel (index ``m``), whose squared distance overflows to +inf.

    Returns ``(cand_x, cand_y, need)``: candidates ``(nb, capacity)`` and the
    true per-block candidate count ``need (nb,)``.  ``need > capacity`` means
    this gather is incomplete and the caller must use the exact fallback.
    ``with_z=True`` additionally gathers the attribute rows (sentinel slot
    z = 0, i.e. weightless) and returns ``(cand_x, cand_y, cand_z, need)`` —
    the far-field Phase 2 needs the z values of its near field.
    """
    nb = xlo.shape[0]
    gx, gy = grid.gx, grid.gy
    rows = jnp.arange(gy, dtype=jnp.int32)[None, :]                 # (1, gy)
    ht = yhi - ylo + 1
    y = ylo[:, None] + rows                                          # (nb, gy)
    row_ok = rows < ht[:, None]
    ysafe = jnp.minimum(y, gy - 1)
    c = grid.cum
    x0 = xlo[:, None]
    x1 = xhi[:, None] + 1
    cnt = c[ysafe + 1, x1] - c[ysafe + 1, x0] - c[ysafe, x1] + c[ysafe, x0]
    cnt = jnp.where(row_ok, cnt, 0)
    offs = jnp.concatenate([jnp.zeros((nb, 1), jnp.int32), jnp.cumsum(cnt, axis=1)], axis=1)
    need = offs[:, -1]

    s = jnp.broadcast_to(jnp.arange(capacity, dtype=jnp.int32)[None, :], (nb, capacity))
    row = jax.vmap(functools.partial(jnp.searchsorted, side="right"))(offs, s) - 1
    row = jnp.clip(row, 0, gy - 1)
    within = s - jnp.take_along_axis(offs, row, axis=1)
    base_cid = (ylo[:, None] + row) * gx + x0
    idx = grid.starts[jnp.clip(base_cid, 0, grid.n_cells)] + within
    m = grid.n_points
    valid = s < jnp.minimum(need, capacity)[:, None]
    idx = jnp.where(valid, jnp.clip(idx, 0, m - 1), m)               # m = sentinel slot
    if with_z:
        return grid.pt_x[idx], grid.pt_y[idx], grid.pt_z[idx], need
    return grid.pt_x[idx], grid.pt_y[idx], need


# Index maps shared by the scalar-prefetch pipelines (Phase-1 skip, Phase-2
# near, Phase-2 far); the first argument after (i, j) is the prefetched
# scalar ref, unused by the query/output maps.
def _pf_query_map(i, j, _scalar):
    return (i, 0)


def _pf_clamped_tile_map(i, j, nt):
    # clamp past-need steps to the block's last real tile: Pallas skips the
    # DMA for a revisited block index, the kernel skips the merge
    return (i, jnp.maximum(jnp.minimum(j, nt[i] - 1), 0))


def _pf_shared_tile_map(i, j, _scalar):
    return (0, j)


def _knn_kernel_skip(nt_ref, qx_ref, qy_ref, dx_ref, dy_ref, alpha_ref, best,
                     *, m_real, area, params):
    """Sparsity-skipping twin of ``_knn_kernel_soa``.

    ``nt_ref`` is the scalar-prefetched per-block tile count: steps past it
    are clamped revisits of the block's last real tile (no DMA) and the
    k-best merge is predicated off, so an all-sentinel tail costs grid
    overhead only.  Init/finish still fire on the first/last *grid* step —
    the output block is written exactly once per query block.
    """
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        best[...] = jnp.full(best.shape, jnp.inf, best.dtype)

    @pl.when(j < nt_ref[i])
    def _merge():
        d2 = sq_dist_tile(qx_ref[...], qy_ref[...], dx_ref[...], dy_ref[...])
        best[...] = merge_k_best(best[...], d2, data_axis=1)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        alpha_ref[...] = alpha_from_best(best[...], m_real, area, params, data_axis=1)


def phase1_alpha_from_candidates(
    qx_s, qy_s, cand_x, cand_y, *,
    params: AIDWParams, area: float, m_real: int,
    block_q: int, block_d: int, interpret: bool,
    num_tiles=None,
):
    """Phase 1 over per-block candidate rows.

    qx_s/qy_s: (n_tot,) Morton-sorted padded queries, ``n_tot % block_q == 0``;
    cand_x/cand_y: (nb, c_tot) with ``c_tot % block_d == 0``.
    Returns alpha, shape ``(n_tot, 1)``.

    ``num_tiles`` (optional ``(nb,)`` int32, ``ceil(covered_need/block_d)``)
    selects the scalar-prefetch pipeline: block ``i``'s candidate index map
    becomes ``min(j, num_tiles[i]-1)`` so its all-sentinel tail tiles are
    never fetched and never merged — the per-block tile table the plan's
    launch-wide capacity cannot express.  ``None`` keeps the dense walk
    (every block streams all ``c_tot // block_d`` tiles); both pipelines
    merge identical non-sentinel candidates, so their alpha agrees exactly.
    """
    n_tot = qx_s.shape[0]
    nb, c_tot = cand_x.shape
    dtype = qx_s.dtype
    qx2, qy2 = qx_s[:, None], qy_s[:, None]
    out_shape = jax.ShapeDtypeStruct((n_tot, 1), dtype)
    scratch = [pltpu.VMEM((block_q, params.k), dtype)]

    if num_tiles is None:
        q_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
        c_spec = pl.BlockSpec((1, block_d), lambda i, j: (i, j))
        o_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
        return pl.pallas_call(
            functools.partial(_knn_kernel_soa, m_real=m_real, area=area, params=params),
            grid=(nb, c_tot // block_d),
            in_specs=[q_spec, q_spec, c_spec, c_spec],
            out_specs=o_spec,
            out_shape=out_shape,
            scratch_shapes=scratch,
            compiler_params=_SEMANTICS,
            interpret=interpret,
        )(qx2, qy2, cand_x, cand_y)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, c_tot // block_d),
        in_specs=[
            pl.BlockSpec((block_q, 1), _pf_query_map),
            pl.BlockSpec((block_q, 1), _pf_query_map),
            pl.BlockSpec((1, block_d), _pf_clamped_tile_map),
            pl.BlockSpec((1, block_d), _pf_clamped_tile_map),
        ],
        out_specs=pl.BlockSpec((block_q, 1), _pf_query_map),
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        functools.partial(_knn_kernel_skip, m_real=m_real, area=area, params=params),
        grid_spec=grid_spec,
        out_shape=out_shape,
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(num_tiles.astype(jnp.int32), qx2, qy2, cand_x, cand_y)


def _near_weight_kernel(nt_ref, qx_ref, qy_ref, ah_ref, dx_ref, dy_ref, dz_ref,
                        sw_ref, swz_ref, md_ref, hz_ref,
                        acc_w, acc_wz, min_d2, hit_z):
    """Near-field half of the far-field Phase 2: ``_weight_kernel_soa`` over
    per-block candidate rows, with the Phase-1 tile-table skip (steps past
    ``nt_ref[i]`` are clamped revisits, the accumulation is predicated off)
    — and the four accumulators written out instead of a finished z, so the
    engine can fold in the far-cell terms before dividing."""
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_w[...] = jnp.zeros(acc_w.shape, acc_w.dtype)
        acc_wz[...] = jnp.zeros(acc_wz.shape, acc_wz.dtype)
        min_d2[...] = jnp.full(min_d2.shape, jnp.inf, min_d2.dtype)
        hit_z[...] = jnp.zeros(hit_z.shape, hit_z.dtype)

    @pl.when(j < nt_ref[i])
    def _accumulate():
        d2 = sq_dist_tile(qx_ref[...], qy_ref[...], dx_ref[...], dy_ref[...])
        sw, swz, tmin, thz = weight_tile(d2, dz_ref[...], ah_ref[...], data_axis=1)
        acc_w[...] += sw
        acc_wz[...] += swz
        better = tmin < min_d2[...]
        hit_z[...] = jnp.where(better, thz, hit_z[...])
        min_d2[...] = jnp.where(better, tmin, min_d2[...])

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        sw_ref[...] = acc_w[...]
        swz_ref[...] = acc_wz[...]
        md_ref[...] = min_d2[...]
        hz_ref[...] = hit_z[...]


def phase2_near_weights(
    qx_s, qy_s, alpha_half, cand_x, cand_y, cand_z, num_tiles, *,
    block_q: int, block_d: int, interpret: bool,
):
    """Exact near-field weight sweep over per-block candidate rows.

    qx_s/qy_s/alpha_half: (n_tot,) / (n_tot, 1), ``n_tot % block_q == 0``;
    cand_*: (nb, c_tot) near-rectangle candidates, ``c_tot % block_d == 0``;
    num_tiles: (nb,) int32 per-block real-tile count (the scalar-prefetch
    tile table; pass the full tile count for a dense walk — bit-identical,
    the skipped tiles are all-sentinel).

    Returns ``(sum_w, sum_wz, min_d2, hit_z)``, each ``(n_tot, 1)``.
    """
    n_tot = qx_s.shape[0]
    nb, c_tot = cand_x.shape
    dtype = qx_s.dtype
    qx2, qy2 = qx_s[:, None], qy_s[:, None]
    q_spec = pl.BlockSpec((block_q, 1), _pf_query_map)
    c_spec = pl.BlockSpec((1, block_d), _pf_clamped_tile_map)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, c_tot // block_d),
        in_specs=[q_spec, q_spec, q_spec, c_spec, c_spec, c_spec],
        out_specs=[q_spec] * 4,
        scratch_shapes=[pltpu.VMEM((block_q, 1), dtype) for _ in range(4)],
    )
    return pl.pallas_call(
        _near_weight_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_tot, 1), dtype)] * 4,
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(num_tiles.astype(jnp.int32), qx2, qy2, alpha_half, cand_x, cand_y, cand_z)


def _far_cell_kernel(rect_ref, qx_ref, qy_ref, ah_ref, fx_ref, fy_ref,
                     fix_ref, fiy_ref, fcnt_ref, fzs_ref,
                     sw_ref, swz_ref, acc_w, acc_wz):
    """Far-field half: one aggregate term per cell OUTSIDE the block's near
    rectangle (scalar-prefetched as ``rect_ref[i] = (xlo, xhi, ylo, yhi)``).

    Each far cell contributes ``count * w(d_centroid)`` to Σw and
    ``z_sum * w(d_centroid)`` to Σw·z.  Cells inside the rectangle are
    masked to 0 — their points were swept exactly by the near kernel — and
    pad cells carry sentinel centroids (w = 0) AND count = z_sum = 0.
    """
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_w[...] = jnp.zeros(acc_w.shape, acc_w.dtype)
        acc_wz[...] = jnp.zeros(acc_wz.shape, acc_wz.dtype)

    d2 = sq_dist_tile(qx_ref[...], qy_ref[...], fx_ref[...], fy_ref[...])
    w = pow_weight(d2, ah_ref[...])
    inside = ((fix_ref[...] >= rect_ref[i, 0]) & (fix_ref[...] <= rect_ref[i, 1])
              & (fiy_ref[...] >= rect_ref[i, 2]) & (fiy_ref[...] <= rect_ref[i, 3]))
    w = jnp.where(inside, jnp.zeros((), d2.dtype), w)
    acc_w[...] += jnp.sum(w * fcnt_ref[...], axis=1, keepdims=True)
    acc_wz[...] += jnp.sum(w * fzs_ref[...], axis=1, keepdims=True)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        sw_ref[...] = acc_w[...]
        swz_ref[...] = acc_wz[...]


def phase2_far_aggregates(
    qx_s, qy_s, alpha_half, rects, far, *,
    block_q: int, block_d: int, interpret: bool,
):
    """Far-field aggregate sweep: every cell of the grid, one term each.

    rects: (nb, 4) int32 per-block near rectangles (inclusive cell bounds,
    masked out of the far sum); far: the plan's padded ``(1, ncp)`` arrays
    ``(cent_x, cent_y, count, z_sum, ix, iy)``, ``ncp % block_d == 0``.

    Returns ``(sum_w_far, sum_wz_far)``, each ``(n_tot, 1)``.
    """
    n_tot = qx_s.shape[0]
    nb = rects.shape[0]
    dtype = qx_s.dtype
    fx, fy, fcnt, fzs, fix, fiy = far
    ncp = fx.shape[1]
    qx2, qy2 = qx_s[:, None], qy_s[:, None]
    q_spec = pl.BlockSpec((block_q, 1), _pf_query_map)
    c_spec = pl.BlockSpec((1, block_d), _pf_shared_tile_map)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, ncp // block_d),
        in_specs=[q_spec, q_spec, q_spec] + [c_spec] * 6,
        out_specs=[q_spec] * 2,
        scratch_shapes=[pltpu.VMEM((block_q, 1), dtype) for _ in range(2)],
    )
    return pl.pallas_call(
        _far_cell_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_tot, 1), dtype)] * 2,
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(rects.astype(jnp.int32), qx2, qy2, alpha_half, fx, fy, fix, fiy, fcnt, fzs)


def _far_node_kernel(nt_ref, qx_ref, qy_ref, ah_ref, fx_ref, fy_ref,
                     fcnt_ref, fzs_ref, fmx_ref, fmy_ref,
                     sw_ref, swz_ref, acc_w, acc_wz):
    """Quadtree far-field level sweep: one aggregate + DIPOLE term per
    closed node of the block's gathered level table (DESIGN.md §8).

    The monopole terms are the far-cell kernel's (``count * w`` / ``z_sum *
    w`` at the centroid distance); the dipole adds ``grad w(cent) . M`` with
    ``M = (mx, my)`` the node's stored first z-moment about its centroid:
    for ``w(p) = |q - p|^-a``, ``grad_p w = a |q - p|^(-a-2) (q - p)``, so
    the term is ``a * w / d2 * ((qx-cx) mx + (qy-cy) my)`` — it cancels the
    z budget's first-order error, which is what makes the plan's quadtree
    bound second-order.  Pad slots of the table point at the plan's
    sentinel node: centroid at the coordinate sentinel (``d2`` overflows to
    +inf, ``w = 0``, ``w / d2 = 0``) and zero count/z-sum/moment, so they
    add exactly 0 to both accumulators.  Steps past ``nt_ref[i]`` are
    clamped revisits with the accumulation predicated off, same tile-table
    discipline as the near kernel.
    """
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_w[...] = jnp.zeros(acc_w.shape, acc_w.dtype)
        acc_wz[...] = jnp.zeros(acc_wz.shape, acc_wz.dtype)

    @pl.when(j < nt_ref[i])
    def _accumulate():
        dqx = qx_ref[...] - fx_ref[...]
        dqy = qy_ref[...] - fy_ref[...]
        d2 = dqx * dqx + dqy * dqy
        ah = ah_ref[...]
        w = pow_weight(d2, ah)
        tiny = jnp.asarray(1e-30 if d2.dtype == jnp.float32 else 1e-290, d2.dtype)
        grad = (2.0 * ah) * w / jnp.maximum(d2, tiny)
        dip = grad * (dqx * fmx_ref[...] + dqy * fmy_ref[...])
        acc_w[...] += jnp.sum(w * fcnt_ref[...], axis=1, keepdims=True)
        acc_wz[...] += jnp.sum(w * fzs_ref[...] + dip, axis=1, keepdims=True)

    @pl.when(j == pl.num_programs(1) - 1)
    def _finish():
        sw_ref[...] = acc_w[...]
        swz_ref[...] = acc_wz[...]


def phase2_far_nodes(
    qx_s, qy_s, alpha_half, node_x, node_y, node_cnt, node_zs, node_mx,
    node_my, num_tiles, *, block_q: int, block_d: int, interpret: bool,
):
    """One quadtree level's far sweep over per-block gathered node tables.

    qx_s/qy_s/alpha_half: (n_tot,) / (n_tot, 1), ``n_tot % block_q == 0``;
    node_*: (nb, k_pad) closed-node aggregates gathered by the engine's
    level walk (pad slots = the sentinel node), ``k_pad % block_d == 0``;
    num_tiles: (nb,) int32 ``ceil(closed_count / block_d)`` — a block with
    few closed nodes at this level walks only its real tiles.

    Returns ``(sum_w_far, sum_wz_far)``, each ``(n_tot, 1)`` — the engine
    accumulates them across levels before the near/far combine.
    """
    n_tot = qx_s.shape[0]
    nb, k_pad = node_x.shape
    dtype = qx_s.dtype
    qx2, qy2 = qx_s[:, None], qy_s[:, None]
    q_spec = pl.BlockSpec((block_q, 1), _pf_query_map)
    c_spec = pl.BlockSpec((1, block_d), _pf_clamped_tile_map)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb, k_pad // block_d),
        in_specs=[q_spec, q_spec, q_spec] + [c_spec] * 6,
        out_specs=[q_spec] * 2,
        scratch_shapes=[pltpu.VMEM((block_q, 1), dtype) for _ in range(2)],
    )
    return pl.pallas_call(
        _far_node_kernel,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((n_tot, 1), dtype)] * 2,
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(num_tiles.astype(jnp.int32), qx2, qy2, alpha_half,
      node_x, node_y, node_cnt, node_zs, node_mx, node_my)


def phase2_weights_full(
    qx_s, qy_s, alpha, dxp, dyp, dzp, *,
    eps: float, block_q: int, block_d: int, interpret: bool,
):
    """Phase 2: full-data weighted sweep (AIDW weights ALL m points).

    dxp/dyp/dzp: (1, mp) sentinel-padded data, ``mp % block_d == 0``.
    Returns z_hat, shape ``(n_tot, 1)``.
    """
    n_tot = qx_s.shape[0]
    dtype = qx_s.dtype
    qx2, qy2 = qx_s[:, None], qy_s[:, None]
    q_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    d_spec = pl.BlockSpec((1, block_d), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((block_q, 1), lambda i, j: (i, 0))
    return pl.pallas_call(
        functools.partial(_weight_kernel_soa, eps=eps),
        grid=(n_tot // block_q, dxp.shape[1] // block_d),
        in_specs=[q_spec, q_spec, q_spec, d_spec, d_spec, d_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((n_tot, 1), dtype),
        scratch_shapes=[pltpu.VMEM((block_q, 1), dtype) for _ in range(4)],
        compiler_params=_SEMANTICS,
        interpret=interpret,
    )(qx2, qy2, alpha * 0.5, dxp, dyp, dzp)
