"""Serving layer: plan registry, capacity re-estimator, fault injection.

The robustness backbone under ROADMAP direction 1 (the continuous-batching
serving engine).  ``PlanRegistry`` owns plan lifetime (bounded LRU, identity
guards, warmup, atomic hot-swap); ``CapacityReestimator`` closes the loop
from the engine's ``persistent_overflow`` streak to a background re-plan +
swap, degrading gracefully when growth is impossible; ``faults`` lets tests
drive every path of that state machine deterministically.  DESIGN.md §9.
"""

from repro.serving import faults
from repro.serving.reestimator import CapacityReestimator
from repro.serving.registry import PlanRegistry, default_registry, plan_key

__all__ = [
    "CapacityReestimator",
    "PlanRegistry",
    "default_registry",
    "faults",
    "plan_key",
]
