"""Capacity re-estimator — the self-healing loop over the overflow streak.

A grid plan's static candidate capacity is sized from an *assumed* serving
density (``query_occupancy``).  A workload that is persistently sparser or
clustered differently keeps paying the exact ring-search blend arm batch
after batch — correct, but at ring-search cost.  PR 5 shipped the trigger
(``engine/execute.py: _note_overflow``, the ``persistent_overflow`` streak);
this module ships the response:

``healthy``
    Every batch is served through the registry's current plan; the observed
    ``cand_need_max`` high-water mark is tracked.
``replanning``
    The streak reached ``PERSISTENT_OVERFLOW_BATCHES``: a background thread
    rebuilds the plan via ``engine.plan.replan_with_capacity`` with a
    geometrically bumped capacity floor — at least ``growth ×`` the current
    capacity AND at least the observed ``cand_need_max``, hard-capped at
    ``min(m, capacity_cap)`` (capacity ``m`` provably cannot overflow:
    a candidate row never needs more than every data point).  Build
    failures retry with exponential backoff, at most ``max_retries``
    attempts.  Serving continues on the OLD plan throughout — exact via
    the blend — and the new plan is published by the registry's atomic
    :meth:`~repro.serving.registry.PlanRegistry.swap` (optionally warmed
    first, so the first post-swap batch doesn't pay the compile).
``degraded``
    The capacity cap left no room to grow, or every build attempt failed:
    re-planning stops, serving continues on the installed plan (results
    stay exact through the ring-search / masked-exact arms, at blend-arm
    cost), and ONE :class:`~repro.errors.PlanDegradedWarning` is emitted —
    on the serving thread, at the next :meth:`~CapacityReestimator.execute`
    (warnings raised on a background thread are invisible to standard
    warning filters and to ``pytest.warns``).  :meth:`reset` re-arms.

Fault-injection points (``serving.faults``): ``reestimator.stats`` (per
batch, the diagnostics dict — fabricate synthetic overflow streaks),
``reestimator.build`` (top of every build attempt — inject failures/slow
builds), ``reestimator.capacity`` (the proposed capacity — force cap
exhaustion).  See DESIGN.md §9 for the full state machine.
"""

from __future__ import annotations

import threading
import time
import warnings

from repro.errors import PlanBuildError, PlanDegradedWarning
from repro.serving import faults

HEALTHY = "healthy"
REPLANNING = "replanning"
DEGRADED = "degraded"


class CapacityReestimator:
    """Serve batches through a registry entry; re-plan + hot-swap on
    persistent overflow; degrade gracefully when re-planning cannot help.

    ``registry``/``key``: where the served plan lives (``plan`` is
    registered under ``key`` if absent).  ``growth``: geometric capacity
    bump per re-plan (> 1).  ``capacity_cap``: hard ceiling on the bumped
    candidate capacity (default: ``plan.m``, itself always an implicit
    cap).  ``max_retries`` / ``backoff``: bounded build retries with
    exponential backoff (``backoff * 2**attempt`` seconds between tries).
    ``warmup``: optional ``(qx, qy)`` batch compiled against every new plan
    before its swap becomes visible — keeps the swap stall off the serving
    path.
    """

    def __init__(self, registry, key, plan, *, growth: float = 2.0,
                 capacity_cap: int | None = None, max_retries: int = 3,
                 backoff: float = 0.05, warmup=None):
        if plan.impl != "grid":
            raise ValueError(
                f"CapacityReestimator requires a grid plan, got impl={plan.impl!r}"
            )
        if not growth > 1.0:
            raise ValueError(f"growth must be > 1, got {growth!r}")
        if max_retries < 1:
            raise ValueError(f"max_retries must be >= 1, got {max_retries!r}")
        if backoff < 0.0:
            raise ValueError(f"backoff must be >= 0, got {backoff!r}")
        self.registry = registry
        self.key = key
        self.growth = float(growth)
        self.capacity_cap = None if capacity_cap is None else int(capacity_cap)
        self.max_retries = int(max_retries)
        self.backoff = float(backoff)
        self._warmup = warmup
        self._lock = threading.Lock()
        self._state = HEALTHY
        self._thread: threading.Thread | None = None
        self._pending_warning: str | None = None
        self._need_max = 0
        self.last_error: PlanBuildError | None = None
        self.counters = {"batches": 0, "triggers": 0, "replans": 0,
                         "build_failures": 0, "swaps": 0, "degraded": 0}
        if key not in registry:
            registry.register(key, plan)

    # ------------------------------------------------------------- serving
    @property
    def plan(self):
        """The currently installed plan (whatever the last swap published)."""
        plan = self.registry.get(self.key)
        if plan is None:
            raise KeyError(
                f"plan under key {self.key!r} is gone from the registry "
                "(evicted?); the re-estimator cannot serve without it"
            )
        return plan

    def execute(self, qx, qy):
        """Serve one batch; returns ``(z, alpha, stats)`` like
        ``engine.execute_with_stats``.

        The overflow streak is advanced with the REAL ``_note_overflow``
        machinery (after the ``reestimator.stats`` injection point, so
        fault-injected synthetic streaks take the production path), and a
        streak trigger launches the background re-plan.  Results are
        whatever the installed plan computes — exact for every arm — so a
        batch served during a re-plan equals the same batch on the old
        plan, and a batch after the swap equals a fresh-plan reference.
        """
        import jax

        from repro.engine.execute import _execute_with_stats_jit, _note_overflow

        plan = self.plan
        z, a, stats = _execute_with_stats_jit(plan, qx, qy)
        if not isinstance(stats["overflow_queries"], jax.core.Tracer):
            stats = dict(faults.fire("reestimator.stats", dict(stats)))
            n_overflow = int(stats["overflow_queries"])
            with self._lock:
                self.counters["batches"] += 1
                self._need_max = max(self._need_max,
                                     int(stats["cand_need_max"]))
            persistent = _note_overflow(plan, n_overflow)
            stats["persistent_overflow"] = persistent
            if persistent:
                self._maybe_replan(plan)
        self._deliver_pending()
        return z, a, stats

    # ------------------------------------------------------ replan machinery
    def _maybe_replan(self, plan):
        # stale evidence guard: a batch in flight while a swap lands carries
        # the OLD plan's streak — re-triggering on it would rebuild a plan
        # that was already replaced (the free-running bench exposed this as
        # a doubled trigger/replan/swap count)
        if self.registry.get(self.key) is not plan:
            return
        with self._lock:
            if self._state != HEALTHY:
                return
            self._state = REPLANNING
            self.counters["triggers"] += 1
            need = self._need_max
            t = threading.Thread(
                target=self._replan, args=(plan, need),
                name="repro-capacity-replan", daemon=True,
            )
            self._thread = t
        t.start()

    def _propose_capacity(self, plan, need: int) -> int:
        cap = plan.m
        if self.capacity_cap is not None:
            cap = min(cap, self.capacity_cap)
        return min(max(int(plan.cand_capacity * self.growth), need), cap)

    def _replan(self, plan, need: int):
        from repro.engine.plan import replan_with_capacity

        try:
            target = int(faults.fire("reestimator.capacity",
                                     self._propose_capacity(plan, need)))
            if target <= plan.cand_capacity:
                self._degrade(
                    f"capacity cap exhausted: current cand_capacity="
                    f"{plan.cand_capacity} already meets the bumped target "
                    f"{target} (cap {self.capacity_cap or plan.m}, m={plan.m})",
                    None,
                )
                return
            last_exc = None
            new_plan = None
            for attempt in range(self.max_retries):
                if attempt and self.backoff > 0.0:
                    time.sleep(self.backoff * (2 ** (attempt - 1)))
                try:
                    faults.fire("reestimator.build")
                    with self._lock:
                        self.counters["replans"] += 1
                    new_plan = replan_with_capacity(
                        plan, min_cand_capacity=target, min_p2_capacity=target
                    )
                    break
                except Exception as exc:  # noqa: BLE001 — any build failure retries
                    last_exc = exc
                    with self._lock:
                        self.counters["build_failures"] += 1
            if new_plan is None:
                self._degrade(
                    f"re-plan to cand_capacity>={target} failed after "
                    f"{self.max_retries} attempts "
                    f"({type(last_exc).__name__}: {last_exc})",
                    last_exc,
                )
                return
            self.registry.swap(self.key, new_plan, warmup=self._warmup)
            with self._lock:
                self.counters["swaps"] += 1
                self._state = HEALTHY
                self._need_max = 0
        except Exception as exc:  # noqa: BLE001 — swap/injection failures degrade too
            self._degrade(f"background re-plan crashed "
                          f"({type(exc).__name__}: {exc})", exc)

    def _degrade(self, reason: str, cause):
        err = PlanBuildError(reason)
        if cause is not None:
            err.__cause__ = cause
        with self._lock:
            self._state = DEGRADED
            self.counters["degraded"] += 1
            self.last_error = err
            self._pending_warning = (
                f"capacity re-estimator degraded: {reason}. Serving continues "
                "on the installed plan — results stay exact through the "
                "ring-search / masked-exact blend arms, at blend-arm cost. "
                "Call reset() to re-arm after addressing the cause."
            )

    def _deliver_pending(self):
        with self._lock:
            msg, self._pending_warning = self._pending_warning, None
        if msg is not None:
            warnings.warn(msg, PlanDegradedWarning, stacklevel=3)

    # ------------------------------------------------------------ lifecycle
    @property
    def state(self) -> str:
        with self._lock:
            return self._state

    def join(self, timeout: float | None = 10.0) -> str:
        """Wait for any in-flight background re-plan; returns the state."""
        with self._lock:
            t = self._thread
        if t is not None:
            t.join(timeout)
        return self.state

    def reset(self):
        """Re-arm a degraded (or mid-streak) re-estimator: back to healthy,
        high-water mark and pending warning cleared.  The installed plan and
        the registry entry are untouched."""
        self.join()
        with self._lock:
            self._state = HEALTHY
            self._need_max = 0
            self._pending_warning = None
            self.last_error = None

    def stats(self) -> dict:
        """Snapshot: counters + state + the installed plan's capacity."""
        with self._lock:
            out = dict(self.counters, state=self._state,
                       need_max=self._need_max)
        out["cand_capacity"] = self.plan.cand_capacity
        return out
