"""Plan registry — the serving layer's source of truth for live plans.

A :class:`PlanRegistry` maps hashable keys (by convention: data identity +
plan statics, see :func:`plan_key`) to :class:`InterpolationPlan` objects,
with the lifetime features a serving process needs and the PR-4 weak-ref
convenience cache in ``kernels/ops.py`` lacked:

* **bounded LRU** — at most ``max_plans`` entries; registering past the
  bound evicts the least-recently-used plan (a plan's padded dataset copy
  is the dominant cost, so the bound is a real memory cap);
* **identity guards** — an entry can hold weak references to the caller's
  data arrays; the entry is evicted when any guard dies (no pinned dataset
  copies) and a ``get`` whose live arrays don't match the guards is a miss
  (id reuse after GC cannot alias a stale plan);
* **counters** — ``hits`` / ``misses`` / ``evictions`` / ``swaps``, read
  via :meth:`stats`;
* **optional warmup** — ``register``/``swap`` accept a ``(qx, qy)`` batch
  and run the jitted ``execute`` on it *before* the plan becomes visible,
  so the first real request after a (re-)registration never pays the
  trace+compile;
* **atomic hot-swap** — :meth:`swap` replaces the plan under a key in one
  lock-protected assignment.  Every builder-side cost (plan construction,
  warmup compile) happens OUTSIDE the lock, so a serving thread calling
  :meth:`get` concurrently with a swap never blocks on a build: it gets
  either the old plan or the new one, both complete — never a torn state.
  This is the re-estimator's publication point (DESIGN.md §9).

All mutation is under one re-entrant lock; the structure is safe to share
between a serving thread and background re-planners.
"""

from __future__ import annotations

import threading
import weakref
from collections import OrderedDict

from repro.serving import faults


def plan_key(dx, dy, dz, config: dict):
    """The conventional registry key for the convenience path: data-array
    identity + the static plan config.  Returns ``None`` when the config is
    unhashable (e.g. a prebuilt ``grid=``) — callers should skip caching.

    Array ids are only trusted while the arrays stay alive and identical,
    which is exactly what the registry's identity guards enforce — always
    pass ``guards=(dx, dy, dz)`` alongside a ``plan_key`` key.
    """
    try:
        key = (id(dx), id(dy), id(dz), tuple(sorted(config.items())))
        hash(key)
    except TypeError:
        return None
    return key


class PlanRegistry:
    """Bounded, counter-instrumented, hot-swappable plan store."""

    def __init__(self, max_plans: int = 8):
        if max_plans < 1:
            raise ValueError(f"max_plans must be >= 1, got {max_plans!r}")
        self.max_plans = int(max_plans)
        # key -> (guards, plan); guards is a tuple of weakrefs (possibly
        # empty).  The tuple layout is load-bearing: kernels/ops.py exposes
        # this dict as the back-compat ``_PLAN_CACHE``.
        self._entries: OrderedDict = OrderedDict()
        # RLock, not Lock: a guard's weakref eviction callback can fire
        # during a GC that happens to run inside a locked section on the
        # same thread
        self._lock = threading.RLock()
        self._counters = {"hits": 0, "misses": 0, "evictions": 0, "swaps": 0}

    # ------------------------------------------------------------- lookup
    def get(self, key, live=None):
        """The plan under ``key``, or ``None`` (counted as hit / miss).

        ``live``: the caller's current data arrays; when the entry has
        identity guards they must match ``live`` exactly (object identity),
        else the entry is dropped and the lookup is a miss.
        """
        with self._lock:
            entry = self._entries.get(key)
            if entry is not None:
                guards, plan = entry
                if self._guards_ok(guards, live):
                    self._counters["hits"] += 1
                    self._entries.move_to_end(key)
                    return plan
                del self._entries[key]
                self._counters["evictions"] += 1
            self._counters["misses"] += 1
            return None

    @staticmethod
    def _guards_ok(guards, live) -> bool:
        if not guards:
            return True
        if live is None:
            return all(ref() is not None for ref in guards)
        return len(guards) == len(live) and all(
            ref() is obj for ref, obj in zip(guards, live)
        )

    # --------------------------------------------------------- population
    def register(self, key, plan, *, guards=(), warmup=None):
        """Insert (or replace) ``plan`` under ``key``; returns ``plan``.

        ``guards``: arrays whose identity/lifetime gate the entry — the
        entry is evicted when any of them is garbage-collected.  Arrays
        that don't support weak references make the entry unguardable; it
        is then NOT stored (matching the old convenience-cache behaviour
        for unweakrefable inputs) and the plan is simply returned.
        ``warmup``: optional ``(qx, qy)`` batch compiled (outside the
        lock) before the entry becomes visible.
        """
        if warmup is not None:
            self._warm(plan, warmup)
        try:
            refs = tuple(
                weakref.ref(a, self._make_evictor(key)) for a in guards
            )
        except TypeError:  # unweakrefable guard (plain list, scalar)
            return plan
        with self._lock:
            self._entries[key] = (refs, plan)
            self._entries.move_to_end(key)
            while len(self._entries) > self.max_plans:
                self._entries.popitem(last=False)
                self._counters["evictions"] += 1
        return plan

    def get_or_build(self, key, build, *, guards=(), warmup=None):
        """``get(key)`` or build-register-return (the memoization shape).

        The build runs outside the lock; under a concurrent-build race the
        last registration wins — both plans are equivalent (same inputs).
        """
        plan = self.get(key, live=guards or None)
        if plan is not None:
            return plan
        return self.register(key, build(), guards=guards, warmup=warmup)

    # ----------------------------------------------------------- hot-swap
    def swap(self, key, plan, *, warmup=None):
        """Atomically replace the plan under ``key``; returns the old plan.

        The publication point for background re-plans: ``warmup`` (and the
        plan build the caller already did) run outside the lock, then the
        entry is replaced in one locked assignment, keeping the existing
        guards.  Raises ``KeyError`` if ``key`` is not registered — a swap
        against an evicted entry must fail loudly rather than resurrect a
        key the LRU already dropped.
        """
        if warmup is not None:
            self._warm(plan, warmup)
        with self._lock:
            if key not in self._entries:
                raise KeyError(key)
            faults.fire("registry.swap", key)
            guards, old = self._entries[key]
            self._entries[key] = (guards, plan)
            self._entries.move_to_end(key)
            self._counters["swaps"] += 1
            return old

    # -------------------------------------------------------------- misc
    @staticmethod
    def _warm(plan, batch):
        import jax

        from repro.engine import execute  # lazy: registry <-> engine

        qx, qy = batch
        jax.block_until_ready(execute(plan, qx, qy))

    def _make_evictor(self, key):
        def _evict(_ref):
            with self._lock:
                if self._entries.pop(key, None) is not None:
                    self._counters["evictions"] += 1

        return _evict

    def clear(self):
        """Drop every entry and zero the counters (test / memory hook)."""
        with self._lock:
            self._entries.clear()
            for k in self._counters:
                self._counters[k] = 0

    def stats(self) -> dict:
        """Snapshot: counters plus the current size."""
        with self._lock:
            return dict(self._counters, size=len(self._entries))

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key) -> bool:
        with self._lock:
            return key in self._entries


# Process-default registry: backs the convenience-API memoization in
# kernels/ops.py (which keeps plan_cache_clear()/_PLAN_CACHE as thin shims
# over it) and is the default home for serving sessions.
_default: PlanRegistry | None = None
_default_lock = threading.Lock()


def default_registry() -> PlanRegistry:
    global _default
    with _default_lock:
        if _default is None:
            _default = PlanRegistry(max_plans=8)
        return _default
