"""Deterministic fault injection for the serving layer (DESIGN.md §9).

The registry/re-estimator state machine has paths that real workloads only
hit under rare, slow, or nondeterministic conditions: plan builds that
throw, builds that take seconds, overflow streaks that need pathological
query distributions, capacity caps that need near-OOM datasets.  This
module lets tests drive every one of those paths deterministically: the
serving code calls :func:`fire` at a small set of NAMED injection points,
and a test arms faults at those points with the :func:`inject` context
manager.  With nothing armed, ``fire`` is a dict lookup returning its
input — the production cost is negligible and there are no code-path
differences between tested and untested behaviour.

Injection points (the complete set — ``inject`` rejects unknown names so a
typo'd test arms nothing silently):

``reestimator.stats``
    Fired on every batch served through ``CapacityReestimator.execute``
    with the diagnostics dict as value, BEFORE the persistent-overflow
    streak is advanced.  A ``transform`` here fabricates synthetic
    overflow streaks (``overflow_queries`` / ``cand_need_max`` overrides)
    that flow through the REAL streak machinery.
``reestimator.build``
    Fired at the top of every background re-plan attempt.  ``error``
    simulates plan-build failures (drives the bounded-retry/backoff and
    degrade paths); ``delay`` simulates slow builds (drives the
    serve-during-replan path).
``reestimator.capacity``
    Fired with the proposed new candidate capacity as value before the
    re-plan.  A ``transform``/``value`` forcing it at or below the current
    capacity simulates capacity-cap exhaustion (the degrade-without-retry
    path).
``registry.swap``
    Fired inside the registry's swap critical section (value: the key).
    ``delay`` widens the swap window so concurrency tests can overlap
    readers with an in-flight swap.

Each armed fault applies, in order: ``delay`` (sleep), ``error`` (raise;
class or instance), then ``transform``/``value`` (replace the value).
``times=N`` disarms the fault after N firings — "fail the first two build
attempts, then succeed" is ``inject("reestimator.build", error=...,
times=2)``.  Faults nest (inner-most armed last fires last) and are
removed on context exit, so a crashed test cannot leak a fault into the
next one.
"""

from __future__ import annotations

import contextlib
import threading
import time

INJECTION_POINTS = (
    "reestimator.stats",
    "reestimator.build",
    "reestimator.capacity",
    "registry.swap",
)

_lock = threading.Lock()
_active: dict[str, list["_Fault"]] = {}


class _Fault:
    """One armed fault.  ``fired`` counts firings (tests assert on it)."""

    def __init__(self, point, *, error=None, delay=0.0, times=None,
                 value=None, transform=None):
        if point not in INJECTION_POINTS:
            raise ValueError(
                f"unknown injection point {point!r}; known points: "
                f"{INJECTION_POINTS}"
            )
        if times is not None and times < 1:
            raise ValueError(f"times must be >= 1, got {times!r}")
        if value is not None and transform is not None:
            raise ValueError("pass value= or transform=, not both")
        self.point = point
        self.error = error
        self.delay = float(delay)
        self.times = times
        self.value = value
        self.transform = transform
        self.fired = 0

    def _take(self) -> bool:
        """Claim one firing (under the module lock). False once exhausted."""
        if self.times is not None and self.fired >= self.times:
            return False
        self.fired += 1
        return True

    def _apply(self, value):
        if self.delay > 0.0:
            time.sleep(self.delay)
        if self.error is not None:
            err = self.error() if isinstance(self.error, type) else self.error
            raise err
        if self.transform is not None:
            return self.transform(value)
        if self.value is not None:
            return self.value
        return value


@contextlib.contextmanager
def inject(point, *, error=None, delay=0.0, times=None, value=None,
           transform=None):
    """Arm a fault at ``point`` for the duration of the ``with`` block.

    Yields the fault object (its ``fired`` counter is the number of times
    the fault actually applied).  See the module docstring for the points
    and the per-firing semantics of ``error``/``delay``/``times``/
    ``value``/``transform``.
    """
    fault = _Fault(point, error=error, delay=delay, times=times, value=value,
                   transform=transform)
    with _lock:
        _active.setdefault(point, []).append(fault)
    try:
        yield fault
    finally:
        with _lock:
            _active[point].remove(fault)
            if not _active[point]:
                del _active[point]


def fire(point, value=None):
    """Apply every armed fault at ``point`` (in arming order) to ``value``.

    Called by the serving code at its injection points; returns the
    (possibly transformed) value.  Raises whatever error an armed fault
    carries.  With nothing armed this is a no-op returning ``value``.
    """
    if point not in INJECTION_POINTS:
        raise ValueError(
            f"unknown injection point {point!r}; known points: "
            f"{INJECTION_POINTS}"
        )
    with _lock:
        taken = [f for f in _active.get(point, ()) if f._take()]
    for fault in taken:
        value = fault._apply(value)
    return value


def active_points() -> tuple:
    """Names of points with at least one armed fault (diagnostic)."""
    with _lock:
        return tuple(sorted(_active))
