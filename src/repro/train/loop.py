"""Fault-tolerant training loop.

Covers the scale-out failure modes the assignment requires, in a form
testable on one host:
  * periodic atomic checkpointing + restart-from-latest on failure;
  * bounded retries (a persistently failing step aborts loudly, it doesn't
    spin);
  * straggler watchdog — a step slower than ``straggler_factor`` x the
    rolling median is logged and counted (at fleet scale this signal drives
    re-slicing / hot-spares; here it is surfaced and unit-tested);
  * deterministic data by (step, host) so restarts and elastic resizes
    replay the exact stream (see data/synthetic.py).
"""

from __future__ import annotations

import statistics
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import numpy as np


class InjectedFailure(RuntimeError):
    """Raised by test failure injectors to simulate node loss."""


@dataclass
class LoopConfig:
    num_steps: int
    ckpt_every: int = 50
    max_retries: int = 3
    straggler_factor: float = 3.0
    log_every: int = 10


@dataclass
class LoopEvents:
    restarts: int = 0
    stragglers: int = 0
    saved_steps: list = field(default_factory=list)


def train_loop(
    step_fn,
    params,
    opt_state,
    batch_fn,
    ckpt,
    loop_cfg: LoopConfig,
    *,
    start_step: int = 0,
    failure_injector=None,
    log=print,
):
    """Run ``loop_cfg.num_steps`` steps with checkpoint/restart semantics.

    batch_fn(step) -> batch dict.  Returns (params, opt_state, events).
    """
    events = LoopEvents()
    times: deque = deque(maxlen=32)
    retries = 0
    step = start_step
    metrics = {}

    while step < loop_cfg.num_steps:
        batch = batch_fn(step)
        t0 = time.perf_counter()
        try:
            if failure_injector is not None:
                failure_injector(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch, step)
            jax.block_until_ready(metrics)
        except InjectedFailure as e:
            retries += 1
            events.restarts += 1
            if retries > loop_cfg.max_retries:
                raise RuntimeError(f"step {step}: exceeded max retries") from e
            latest = ckpt.latest_step()
            log(f"[loop] failure at step {step} ({e}); restoring ckpt step {latest}")
            if latest is not None:
                state, restored = ckpt.restore({"params": params, "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                step = restored + 1
            else:
                step = start_step
            continue
        retries = 0
        dt = time.perf_counter() - t0
        if len(times) >= 5:
            med = statistics.median(times)
            if dt > loop_cfg.straggler_factor * med:
                events.stragglers += 1
                log(f"[loop] straggler: step {step} took {dt:.3f}s (median {med:.3f}s)")
        times.append(dt)
        if step % loop_cfg.ckpt_every == 0:
            ckpt.save(step, {"params": params, "opt": opt_state})
            events.saved_steps.append(step)
        if step % loop_cfg.log_every == 0:
            loss = float(np.asarray(metrics.get("loss", np.nan)))
            log(f"[loop] step {step} loss {loss:.4f} ({dt*1e3:.0f} ms)")
        step += 1
    return params, opt_state, events
