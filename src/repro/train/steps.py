"""Step functions: train (grad-accum + AdamW), prefill, decode.

These are the functions the multi-pod dry-run lowers; sharding enters only
through (a) in/out shardings applied by the caller's jit and (b) the ambient
logical-axis rule context (activation constraints).
"""

from __future__ import annotations

import contextlib
import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.optim import (
    AdamWConfig,
    adamw_update,
    clip_by_global_norm,
    compress_tree,
    warmup_cosine,
)
from repro.sharding.rules import use_rules


def cross_entropy(logits, labels):
    """Mean next-token CE.  logits (B, S, V) f32, labels (B, S) int32."""
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


_MICRO_BATCH_AXIS = {"mrope_positions": 1}  # (3, B, S) — batch on axis 1


def _split_micro(batch, accum: int):
    def one(key, v):
        ax = _MICRO_BATCH_AXIS.get(key, 0)
        shape = v.shape
        new = shape[:ax] + (accum, shape[ax] // accum) + shape[ax + 1 :]
        return jnp.moveaxis(v.reshape(new), ax, 0)

    return {k: one(k, v) for k, v in batch.items()}


def make_loss_fn(model, cfg, *, remat: bool = True, lb_coef: float = 1e-2, z_coef: float = 1e-3, unroll: bool = False):
    def loss_fn(params, micro):
        kw = {}
        if cfg.family == "audio":
            kw["frames"] = micro["frames"]
        h, _, aux = model.apply(params, micro["tokens"], mode="train", extra=micro, remat=remat, unroll=unroll, **kw)
        logits = model.logits(params, h)
        ce = cross_entropy(logits, micro["labels"])
        loss = ce + lb_coef * aux["lb_loss"] + z_coef * aux["z_loss"]
        return loss, {"ce": ce, **aux}

    return loss_fn


def make_train_step(
    model,
    cfg,
    shape,
    *,
    opt: AdamWConfig = AdamWConfig(),
    mesh=None,
    rules=None,
    remat: bool = True,
    compress_grads: bool = False,
    unroll: bool = False,
    schedule=functools.partial(warmup_cosine, warmup=100, total=10_000),
):
    """Returns train_step(params, opt_state, batch, step) -> (params, opt_state, metrics).

    Gradient accumulation over ``shape.accum_steps`` microbatches via scan;
    optional bf16 gradient compression during accumulation (halves the bytes
    the cross-pod all-reduce moves — see optim/grad_utils.py).
    """
    accum = max(shape.accum_steps, 1)
    loss_fn = make_loss_fn(model, cfg, remat=remat, unroll=unroll)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch, step):
        ctx = use_rules(mesh, rules) if mesh is not None else contextlib.nullcontext()
        with ctx:
            micro = _split_micro(batch, accum)
            acc_dtype = jnp.bfloat16 if compress_grads else jnp.float32

            def body(carry, mb):
                gacc, lacc = carry
                (loss, _), g = grad_fn(params, mb)
                g = compress_tree(g, acc_dtype)
                gacc = jax.tree.map(lambda a, b: a + b.astype(a.dtype), gacc, g)
                return (gacc, lacc + loss), None

            gacc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (gacc, loss_sum), _ = jax.lax.scan(body, (gacc0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32) / accum, gacc)
            grads, gnorm = clip_by_global_norm(grads, opt.grad_clip)
            lr_scale = schedule(step)
            params, opt_state = adamw_update(grads, opt_state, params, opt, lr_scale)
            metrics = {
                "loss": loss_sum / accum,
                "grad_norm": gnorm,
                "lr": opt.lr * lr_scale,
            }
            return params, opt_state, metrics

    return train_step


def make_prefill_step(model, cfg, *, mesh=None, rules=None, unroll: bool = False):
    """prefill_step(params, batch) -> (last_logits (B, V), caches)."""

    def prefill_step(params, batch):
        ctx = use_rules(mesh, rules) if mesh is not None else contextlib.nullcontext()
        with ctx:
            kw = {}
            if cfg.family == "audio":
                kw["frames"] = batch["frames"]
            h, caches, _ = model.apply(params, batch["tokens"], mode="prefill", extra=batch, unroll=unroll, **kw)
            logits = model.logits(params, h[:, -1:, :])[:, 0]
            return logits, caches

    return prefill_step


def make_serve_step(model, cfg, *, mesh=None, rules=None, unroll: bool = False):
    """serve_step(params, caches, tokens (B,1), pos) ->
    (next_token (B,1), logits (B,V), new_caches)."""

    def serve_step(params, caches, tokens, pos):
        ctx = use_rules(mesh, rules) if mesh is not None else contextlib.nullcontext()
        with ctx:
            h, new_caches, _ = model.apply(params, tokens, mode="decode", caches=caches, pos=pos, unroll=unroll)
            logits = model.logits(params, h)[:, 0]
            next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
            return next_token, logits, new_caches

    return serve_step


def pad_caches(caches, target_seq: int):
    """Grow prefill caches to decode capacity along the cache_seq axis.
    KV leaves are (G, B, S, kv, hd) (axis 2); SSM/conv/cross leaves pass
    through untouched."""

    def pad(path, a):
        names = [str(getattr(p, "key", "")) for p in path]
        if names and names[-1] in ("k", "v") and "cross" not in names:
            w = [(0, 0)] * a.ndim
            w[2] = (0, target_seq - a.shape[2])
            return jnp.pad(a, w)
        return a

    return jax.tree_util.tree_map_with_path(pad, caches)
