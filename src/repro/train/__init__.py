from repro.train.steps import (
    cross_entropy,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    pad_caches,
)
from repro.train.loop import InjectedFailure, LoopConfig, train_loop

__all__ = [
    "cross_entropy",
    "make_prefill_step",
    "make_serve_step",
    "make_train_step",
    "pad_caches",
    "InjectedFailure",
    "LoopConfig",
    "train_loop",
]
