from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update, opt_state_axes
from repro.optim.schedules import warmup_cosine
from repro.optim.grad_utils import clip_by_global_norm, compress_tree, global_norm

__all__ = [
    "AdamWConfig",
    "adamw_init",
    "adamw_update",
    "opt_state_axes",
    "warmup_cosine",
    "clip_by_global_norm",
    "compress_tree",
    "global_norm",
]
