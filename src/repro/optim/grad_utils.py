"""Gradient utilities: global-norm clipping, cross-pod gradient compression.

Compression: the cross-pod (DCN) all-reduce is the slowest collective in a
multi-pod job.  ``compress_tree`` casts the accumulated gradients to bf16
*before* they cross the pod axis (halving DCN bytes) and back to f32 after —
the classic 16-bit gradient-compression trick.  In the pjit data flow this is
expressed by accumulating microbatch grads in bf16 and upcasting at the
optimizer boundary; the §Perf log quantifies the collective-byte reduction
from the compiled HLO.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale).astype(l.dtype), tree), norm


def compress_tree(tree, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda l: l.astype(dtype) if jnp.issubdtype(l.dtype, jnp.floating) else l, tree
    )
