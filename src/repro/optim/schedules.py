"""Learning-rate schedules (return a multiplier on the base lr)."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(step, *, warmup: int = 100, total: int = 10_000, min_frac: float = 0.1):
    s = step.astype(jnp.float32) if hasattr(step, "astype") else jnp.float32(step)
    warm = jnp.minimum((s + 1.0) / max(warmup, 1), 1.0)  # step 0 trains too
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
