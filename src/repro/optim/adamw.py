"""AdamW (decoupled weight decay), built from scratch (no optax on the box).

ZeRO-1: the (m, v) moment trees reuse the parameters' logical axes, and the
train rule set shards the "embed" axis over the data axis — so optimizer
state is sharded exactly like FSDP params, never replicated.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_axes(param_axes):
    """Logical axes for the optimizer state, mirroring the params."""
    return {
        "m": param_axes,
        "v": param_axes,
        "step": (),
    }


def adamw_update(grads, opt_state, params, cfg: AdamWConfig, lr_scale=1.0):
    """One AdamW step.  ``lr_scale`` is the schedule multiplier (traced ok).
    Returns (new_params, new_opt_state)."""
    step = opt_state["step"] + 1
    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        pf = p.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * (g * g)
        mh = m / c1
        vh = v / c2
        new_p = pf - lr * (mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * pf)
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}
