"""Logical-axis -> mesh-axis sharding rule engine (MaxText-style).

Every parameter and activation in the models carries *logical* axis names
("embed", "heads", "batch", "cache_seq", ...).  A rule set maps each logical
axis to an ordered tuple of candidate mesh axes; the mapping is
*divisibility-aware*: the longest prefix of candidate mesh axes whose size
product divides the dimension is used, otherwise the dim is replicated.
This is what lets one rule table serve 24-head and 32-head models, 51865-
and 262144-token vocabularies, on 16- or 512-chip meshes, without per-arch
special cases.

Rule sets per workload kind (see DESIGN.md §6):

* train   — batch over (pod, data); FSDP: "embed" params over data; TP:
            heads/mlp/vocab/experts over model.
* prefill — inference: weights TP only (no FSDP gathers on the latency
            path), batch over (pod, data).
* decode  — like prefill; the KV cache's sequence axis is context-parallel
            over "model" when kv-heads don't divide (GSPMD turns the
            attention reduction into an all-reduce over partial softmax
            stats).
* long    — batch=1 long-context decode: cache sequence over (data, model),
            weights TP.
"""

from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

RULE_SETS: dict[str, dict[str, tuple[str, ...]]] = {
    "train": {
        "batch": ("pod", "data"),
        "seq": (),
        "embed": ("data",),          # FSDP / ZeRO-3 on the intra-pod axis
        "heads": ("model",),
        "kv": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_mlp": ("model",),
        "layers": (),
        "state": (),
        "conv": (),
        "cache_seq": (),
        "act_embed": (),             # activations' d_model stays unsharded
        "cap": (),
    },
    "prefill": {
        "batch": ("pod", "data"),
        "seq": (),
        "embed": (),
        "heads": ("model",),
        "kv": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_mlp": ("model",),
        "layers": (),
        "state": (),
        "conv": (),
        "cache_seq": (),
        "act_embed": (),
        "cap": (),
    },
    "decode": {
        "batch": ("pod", "data"),
        "seq": (),
        "embed": (),
        "heads": ("model",),
        "kv": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_mlp": ("model",),
        "layers": (),
        "state": (),
        "conv": (),
        "cache_seq": ("model",),     # context-parallel KV cache
        "act_embed": (),
        "cap": (),
    },
    # §Perf hillclimb variant: context-parallel prefill.  Activations are
    # sharded on SEQUENCE over the model axis (MLP/norms turn collective-free)
    # and weights on the CONTRACTING d_model dim (so each matmul psums its
    # output — per-device output bytes are 1/model_parallelism of the TP
    # activation all-reduce).  Attention all-gathers K/V, which is cheap for
    # GQA archs (kv << heads).  See EXPERIMENTS §Perf iteration A.
    "prefill_cp": {
        "batch": ("pod", "data"),
        "seq": ("model",),
        "embed": ("model",),
        "heads": (),
        "kv": (),
        "mlp": (),
        "vocab": (),
        "experts": ("model",),
        "expert_mlp": ("model",),
        "layers": (),
        "state": (),
        "conv": (),
        "cache_seq": ("model",),
        "act_embed": (),
        "cap": (),
    },
    "long": {
        "batch": (),
        "seq": (),
        "embed": (),
        "heads": ("model",),
        "kv": ("model",),
        "mlp": ("model",),
        "vocab": ("model",),
        "experts": ("model",),
        "expert_mlp": ("model",),
        "layers": (),
        "state": (),
        "conv": (),
        "cache_seq": ("data", "model"),  # batch=1: all parallelism into the cache
        "act_embed": (),
        "cap": (),
    },
}


def spec_for(logical_axes, dims, rules: dict, mesh: Mesh) -> P:
    """PartitionSpec for one array: per dim, take the longest prefix of the
    rule's mesh axes whose total size divides the dim (and is present in the
    mesh); otherwise replicate that dim."""
    entries = []
    used = set()
    for ax, dim in zip(logical_axes, dims):
        cands = rules.get(ax, ()) if ax is not None else ()
        chosen = []
        prod = 1
        for m in cands:
            if m not in mesh.shape or m in used:
                continue
            size = mesh.shape[m]
            if dim % (prod * size) == 0:
                chosen.append(m)
                prod *= size
            else:
                break
        for m in chosen:
            used.add(m)
        entries.append(tuple(chosen) if len(chosen) > 1 else (chosen[0] if chosen else None))
    return P(*entries)


def sharding_for(logical_axes, dims, rules, mesh) -> NamedSharding:
    return NamedSharding(mesh, spec_for(logical_axes, dims, rules, mesh))


def tree_shardings(axes_tree, shape_tree, rules, mesh):
    """NamedSharding tree for a (axes, arrays) pair of matching trees.
    Logical-axis leaves are tuples, so flatten relative to the array tree."""
    leaves, treedef = jax.tree.flatten(shape_tree)
    axes_leaves = treedef.flatten_up_to(axes_tree)
    return treedef.unflatten(
        [sharding_for(ax, a.shape, rules, mesh) for ax, a in zip(axes_leaves, leaves)]
    )


# ------------------------------------------------------- activation context
# Activation sharding constraints are injected via an ambient context so the
# model code stays mesh-agnostic (identity when no context is active — e.g.
# smoke tests on one device).
_ctx = threading.local()


@contextlib.contextmanager
def use_rules(mesh: Mesh, rules: dict):
    prev = getattr(_ctx, "state", None)
    _ctx.state = (mesh, rules)
    try:
        yield
    finally:
        _ctx.state = prev


def logical_constraint(x, logical_axes):
    """with_sharding_constraint against the ambient rule set (no-op outside)."""
    state = getattr(_ctx, "state", None)
    if state is None:
        return x
    mesh, rules = state
    spec = spec_for(logical_axes, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
