from repro.sharding.rules import (
    RULE_SETS,
    logical_constraint,
    use_rules,
    spec_for,
    sharding_for,
    tree_shardings,
)

__all__ = [
    "RULE_SETS",
    "logical_constraint",
    "use_rules",
    "spec_for",
    "sharding_for",
    "tree_shardings",
]
