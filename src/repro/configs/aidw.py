"""AIDW workload configs — the paper's own workloads as first-class citizens
of the same launcher/dry-run/roofline machinery as the LM archs.

Paper sizes (§4): 10K..1000K points, data == query count, unit square.
Production sizes (beyond paper): pod/multi-pod scale where the data set
itself must be ring-sharded.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.aidw import AIDWParams


@dataclass(frozen=True)
class AIDWWorkload:
    name: str
    m: int  # data points
    n: int  # interpolated points
    k: int = 10
    mode: str = "ring"  # "ring" (data sharded) | "replicated" (queries only)
    q_chunk: int = 1024
    d_chunk: int = 2048

    @property
    def params(self) -> AIDWParams:
        return AIDWParams(k=self.k, area=1.0)


# paper's Table-1 sizes (1K = 1024)
PAPER_SIZES = {f"{s}K": s * 1024 for s in (10, 50, 100, 500, 1000)}

AIDW_WORKLOADS = {
    # paper-scale, single chip handles it, queries sharded, data replicated
    "aidw-pod-1m": AIDWWorkload("aidw-pod-1m", m=1 << 20, n=1 << 20, mode="replicated"),
    # production-scale: 2^27 data points (134M) x 2^24 queries — data must be
    # ring-sharded (beyond paper: this cannot run on the paper's single GPU)
    "aidw-ring-134m": AIDWWorkload("aidw-ring-134m", m=1 << 27, n=1 << 24, mode="ring"),
    # §Perf hillclimb: same workload, queries+state rotate instead of data
    "aidw-ringq-134m": AIDWWorkload("aidw-ringq-134m", m=1 << 27, n=1 << 24, mode="ring_q"),
}
