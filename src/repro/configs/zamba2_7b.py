"""zamba2-7b [hybrid]: 81 Mamba2 layers (d=3584, d_state=64) with ONE
shared-weight attention+MLP block (32H over concat width 2d=7168, head_dim
224, ff=14336) applied every 6 layers (13 applications), Zamba-style.
Organised as 13 scanned groups of (shared-attn -> 6 mamba) + 3 trailing
mamba layers.  [arXiv:2411.15242; unverified]"""

from repro.configs.base import ArchConfig, GroupDef

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,  # informational; the shared block uses shared_head_dim=224
    d_ff=14336,
    vocab_size=32000,
    groups=(
        GroupDef(pattern=(("mamba", None),) * 6, repeats=13, shared_prefix=True),
        GroupDef(pattern=(("mamba", None),) * 3, repeats=1),
    ),
    ssm_state=64,
    ssm_headdim=64,
    ssm_chunk=256,
    ssm_conv=4,
    ssm_expand=2,
    shared_block=True,
    act="geglu",
    tie_embeddings=True,
    sub_quadratic=True,  # Mamba state + 13 shared-attn caches
    source="arXiv:2411.15242",
)
