"""minitron-4b [dense]: 32L, d=3072, 24H GQA kv=8, ff=9216, vocab=256000
(pruned nemotron).  [arXiv:2407.14679; hf]"""

from repro.configs.base import ArchConfig, uniform_groups

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9216,
    vocab_size=256000,
    groups=uniform_groups(32),
    act="swiglu",
    tie_embeddings=False,
    sub_quadratic=False,
    source="arXiv:2407.14679",
)
