"""Architecture registry: the 10 assigned archs + AIDW workload configs."""

from repro.configs.base import SHAPES, ArchConfig, GroupDef, ShapeConfig, smoke

from repro.configs import (  # noqa: E402
    gemma3_27b,
    mamba2_130m,
    minitron_4b,
    mixtral_8x7b,
    qwen1_5_32b,
    qwen2_vl_72b,
    qwen3_moe_30b_a3b,
    stablelm_12b,
    whisper_medium,
    zamba2_7b,
)

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        whisper_medium,
        minitron_4b,
        stablelm_12b,
        gemma3_27b,
        qwen1_5_32b,
        mamba2_130m,
        mixtral_8x7b,
        qwen3_moe_30b_a3b,
        qwen2_vl_72b,
        zamba2_7b,
    )
}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    if name not in SHAPES:
        raise KeyError(f"unknown shape {name!r}; available: {sorted(SHAPES)}")
    return SHAPES[name]


def cell_is_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """The 40-cell applicability matrix (DESIGN.md §4)."""
    if shape.kind == "long" and not arch.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (needs sub-quadratic attention)"
    return True, ""


__all__ = [
    "ARCHS",
    "SHAPES",
    "ArchConfig",
    "GroupDef",
    "ShapeConfig",
    "smoke",
    "get_arch",
    "get_shape",
    "cell_is_applicable",
]
