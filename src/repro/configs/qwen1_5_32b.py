"""qwen1.5-32b [dense]: 64L, d=5120, 40H (kv=40, MHA), ff=27392, QKV bias,
vocab=152064.  [hf:Qwen/Qwen1.5-32B; hf]"""

from repro.configs.base import ArchConfig, uniform_groups

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    head_dim=128,
    d_ff=27392,
    vocab_size=152064,
    groups=uniform_groups(64),
    qkv_bias=True,
    act="swiglu",
    tie_embeddings=False,
    sub_quadratic=False,
    source="hf:Qwen/Qwen1.5-32B",
)
