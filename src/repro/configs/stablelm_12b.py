"""stablelm-12b [dense]: 40L, d=5120, 32H GQA kv=8 (head_dim 160), ff=13824,
vocab=100352.  [hf:stabilityai/stablelm-2-12b; hf]"""

from repro.configs.base import ArchConfig, uniform_groups

CONFIG = ArchConfig(
    name="stablelm-12b",
    family="dense",
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab_size=100352,
    groups=uniform_groups(40),
    act="swiglu",
    tie_embeddings=False,
    sub_quadratic=False,
    source="hf:stabilityai/stablelm-2-12b",
)
