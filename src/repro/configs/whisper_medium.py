"""whisper-medium [audio]: enc-dec, 24L(+24 enc), d=1024, 16H MHA, ff=4096,
vocab=51865.  Conv audio frontend is a stub — input_specs feeds precomputed
frame embeddings.  [arXiv:2212.04356; unverified]"""

from repro.configs.base import ArchConfig, uniform_groups

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    groups=uniform_groups(24),
    n_enc_layers=24,
    act="gelu",
    use_rope=False,  # Whisper: sinusoidal positions
    tie_embeddings=True,
    sub_quadratic=False,  # full attention -> long_500k skipped (DESIGN §4)
    source="arXiv:2212.04356",
)
