"""qwen3-moe-30b-a3b [moe]: 48L, d=2048, 32H GQA kv=4 (head_dim 64),
MoE 128 experts top-8 (expert ff=768), vocab=151936.
[hf:Qwen/Qwen3-30B-A3B; hf]"""

from repro.configs.base import ArchConfig, GroupDef

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=64,
    d_ff=768,
    vocab_size=151936,
    groups=(GroupDef(pattern=(("attn", "moe"),), repeats=48),),
    n_experts=128,
    moe_top_k=8,
    d_ff_expert=768,
    act="swiglu",
    tie_embeddings=False,
    sub_quadratic=False,
    source="hf:Qwen/Qwen3-30B-A3B",
)
