"""mixtral-8x7b [moe]: 32L, d=4096, 32H GQA kv=8, MoE 8 experts top-2
(expert ff=14336), SWA 4096, vocab=32000.  [arXiv:2401.04088; hf]"""

from repro.configs.base import ArchConfig, GroupDef

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    groups=(GroupDef(pattern=(("local", "moe"),), repeats=32),),
    sliding_window=4096,
    windowed_cache=True,  # §Perf E: ring-buffer decode caches for local layers
    n_experts=8,
    moe_top_k=2,
    d_ff_expert=14336,
    act="swiglu",
    tie_embeddings=False,
    sub_quadratic=True,  # sliding-window attention -> bounded decode cache
    source="arXiv:2401.04088",
)
