"""gemma3-27b [dense]: 62L as 10x(5 local + 1 global) + 2 local, d=5376,
32H GQA kv=16, head_dim 128 (deployed size; 5376/32=168 is not used by the
real model), ff=21504, vocab=262144, sliding window 1024, GeGLU.
5:1 local:global + 128k context.  [hf:google/gemma-3-27b-pt; unverified]"""

from repro.configs.base import ArchConfig, GroupDef

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    d_model=5376,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262144,
    groups=(
        GroupDef(pattern=(("local", "dense"),) * 5 + (("attn", "dense"),), repeats=10),
        GroupDef(pattern=(("local", "dense"),) * 2, repeats=1),
    ),
    act="geglu",
    rope_theta=1_000_000.0,
    sliding_window=1024,
    windowed_cache=True,  # §Perf E: ring-buffer decode caches for local layers
    tie_embeddings=True,
    # 5:1 local:global: decode reads are window-bounded on locals; eligible
    # for long_500k (globals are linear-in-S decode reads, not quadratic).
    sub_quadratic=True,
    source="arXiv:2503.19786",
)
