"""qwen2-vl-72b [vlm]: 80L, d=8192, 64H GQA kv=8, ff=29568, vocab=152064,
M-RoPE (t/h/w sections 16/24/24 of head_dim/2), dynamic resolution.
Vision frontend is a STUB per the assignment — input_specs feeds precomputed
patch embeddings merged ahead of the text tokens, and M-RoPE position ids.
[arXiv:2409.12191; hf]"""

from repro.configs.base import ArchConfig, uniform_groups

CONFIG = ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    groups=uniform_groups(80),
    qkv_bias=True,
    mrope_sections=(16, 24, 24),
    n_vis_tokens=256,
    act="swiglu",
    tie_embeddings=False,
    sub_quadratic=False,
    source="arXiv:2409.12191",
)
