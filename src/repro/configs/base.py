"""Config schema: architectures (the assigned pool) and input shapes."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class GroupDef:
    """A scanned stack of identical layer groups.

    pattern: per-layer (mixer, ff) kinds within one group;
      mixer in {"attn" (full causal), "local" (sliding window), "mamba",
                "bidir" (encoder)}; ff in {"dense", "moe", None}.
    repeats: scan length (number of groups).
    shared_prefix: apply the arch's shared attention block (Zamba-style)
      before each repeat of this group.
    """

    pattern: tuple
    repeats: int
    shared_prefix: bool = False


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio (enc-dec)
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    groups: tuple  # tuple[GroupDef, ...] — the decoder stack
    n_enc_layers: int = 0  # encoder stack (enc-dec archs)
    qkv_bias: bool = False
    act: str = "swiglu"
    rope_theta: float = 10000.0
    use_rope: bool = True
    sliding_window: int | None = None
    mrope_sections: tuple | None = None
    n_vis_tokens: int = 0
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    d_ff_expert: int = 0
    moe_capacity_factor: float = 1.25  # GShard-style; tokens above capacity drop
    # SSM (Mamba2)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_expand: int = 2
    # hybrid
    shared_block: bool = False
    # decode: window-sized ring-buffer caches for sliding-window layers
    windowed_cache: bool = False
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    # capabilities
    sub_quadratic: bool = False  # eligible for long_500k (decode-state bounded)
    source: str = ""

    @property
    def n_layers(self) -> int:
        return sum(len(g.pattern) * g.repeats for g in self.groups)

    @property
    def shared_d(self) -> int:
        return 2 * self.d_model  # Zamba-style shared block width

    @property
    def shared_head_dim(self) -> int:
        return self.shared_d // self.n_heads


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    kind: str  # train | prefill | decode | long (long lowers serve_step too)
    seq_len: int
    global_batch: int
    accum_steps: int = 1  # gradient-accumulation microbatches (train only)

    @property
    def step(self) -> str:
        return {"train": "train_step", "prefill": "prefill_step"}.get(self.kind, "serve_step")


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256, accum_steps=8),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "long", 524288, 1),
}


def uniform_groups(n_layers: int, mixer: str = "attn", ff: str | None = "dense"):
    return (GroupDef(pattern=((mixer, ff),), repeats=n_layers),)


def smoke(cfg: ArchConfig) -> ArchConfig:
    """Reduced same-family config for CPU smoke tests (small widths, few
    layers/experts/states, tiny vocab) — shape-generic across the pool."""
    groups = tuple(
        dataclasses.replace(g, repeats=min(g.repeats, 2)) for g in cfg.groups
    )
    head_dim = 16
    return dataclasses.replace(
        cfg,
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads >= cfg.n_heads else 2,
        head_dim=head_dim,
        d_ff=128,
        vocab_size=256,
        groups=groups,
        n_enc_layers=min(cfg.n_enc_layers, 2),
        sliding_window=8 if cfg.sliding_window else None,
        mrope_sections=(4, 2, 2) if cfg.mrope_sections else None,
        n_vis_tokens=8 if cfg.n_vis_tokens else 0,
        n_experts=min(cfg.n_experts, 8),
        moe_top_k=min(cfg.moe_top_k, 2),
        d_ff_expert=32 if cfg.d_ff_expert else 0,
        ssm_state=16 if cfg.ssm_state else 0,
        ssm_headdim=8,
        ssm_chunk=16,
    )
