"""mamba2-130m [ssm]: 24L, d=768, attention-free SSD, d_state=128,
vocab=50280.  [arXiv:2405.21060; unverified]"""

from repro.configs.base import ArchConfig, uniform_groups

CONFIG = ArchConfig(
    name="mamba2-130m",
    family="ssm",
    d_model=768,
    n_heads=0,
    n_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    groups=uniform_groups(24, mixer="mamba", ff=None),
    ssm_state=128,
    ssm_headdim=64,
    ssm_chunk=256,
    ssm_conv=4,
    ssm_expand=2,
    tie_embeddings=True,
    sub_quadratic=True,  # O(1) decode state
    source="arXiv:2405.21060",
)
