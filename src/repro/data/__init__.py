from repro.data.synthetic import SyntheticTokens
from repro.data.spatial import clustered_points, uniform_points
from repro.data.pipeline import HostDataPipeline

__all__ = ["SyntheticTokens", "clustered_points", "uniform_points", "HostDataPipeline"]
