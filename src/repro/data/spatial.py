"""Spatial point-set generators for the AIDW workloads (paper §4: random
points in a square; clustered variants exercise the adaptive-alpha range)."""

from __future__ import annotations

import numpy as np


def uniform_points(m: int, seed: int = 0, dtype=np.float32):
    """The paper's test data: uniform random in the unit square."""
    rng = np.random.default_rng(seed)
    x = rng.random(m).astype(dtype)
    y = rng.random(m).astype(dtype)
    z = (np.sin(6 * x) * np.cos(6 * y) + 2.0).astype(dtype)
    return x, y, z


def clustered_points(m: int, seed: int = 0, n_clusters: int | None = None, spread: float = 0.02, dtype=np.float32):
    rng = np.random.default_rng(seed)
    nc = n_clusters or max(2, m // 256)
    centers = rng.random((nc, 2))
    pts = np.clip(centers[rng.integers(0, nc, m)] + rng.normal(0, spread, (m, 2)), 0, 1)
    x = pts[:, 0].astype(dtype)
    y = pts[:, 1].astype(dtype)
    z = (np.sin(6 * x) * np.cos(6 * y) + 2.0).astype(dtype)
    return x, y, z
