"""Deterministic synthetic LM token stream.

Key property for fleet-scale training: any (step, host) slice is computable
*independently* — no coordinator, no filesystem, bitwise identical across
restarts and across elastic resizes (the global batch for step s does not
depend on how many hosts consume it).  This is the straggler-free data story
referenced in DESIGN.md §6.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab_size: int
    global_batch: int
    seq_len: int
    seed: int = 0

    def _key(self, step: int):
        return jax.random.fold_in(jax.random.PRNGKey(self.seed), step)

    def global_batch_at(self, step: int):
        """Full (GB, S+1) token block; [:, :-1] inputs, [:, 1:] labels."""
        k = self._key(step)
        toks = jax.random.randint(
            k, (self.global_batch, self.seq_len + 1), 0, self.vocab_size, jnp.int32
        )
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def host_batch_at(self, step: int, host_id: int, num_hosts: int):
        """This host's contiguous slice of the *same* global stream."""
        assert self.global_batch % num_hosts == 0
        per = self.global_batch // num_hosts
        full = self.global_batch_at(step)
        sl = slice(host_id * per, (host_id + 1) * per)
        return {k: v[sl] for k, v in full.items()}


def batch_for_arch(cfg, shape, step: int = 0, *, reduced_batch: int | None = None, reduced_seq: int | None = None, seed: int = 0):
    """Concrete numpy batch for train smoke runs, including the modality
    stubs (frames / visual embeds / M-RoPE positions)."""
    b = reduced_batch or shape.global_batch
    s = reduced_seq or shape.seq_len
    ds = SyntheticTokens(cfg.vocab_size, b, s, seed=seed)
    batch = dict(ds.global_batch_at(step))
    rng = np.random.default_rng(seed + step)
    if cfg.family == "audio":
        batch["frames"] = jnp.asarray(rng.normal(size=(b, s, cfg.d_model)).astype(np.float32) * 0.1)
    if cfg.family == "vlm":
        batch["visual_embeds"] = jnp.asarray(
            rng.normal(size=(b, cfg.n_vis_tokens, cfg.d_model)).astype(np.float32) * 0.1
        )
        pos = np.broadcast_to(np.arange(s, dtype=np.int32), (b, s))
        batch["mrope_positions"] = jnp.asarray(np.broadcast_to(pos, (3, b, s)).copy())
    return batch
