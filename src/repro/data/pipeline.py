"""Host-side input pipeline: deterministic sharded batches with prefetch.

Single-process here, but the interfaces are multi-host: each host computes
its own slice from (step, host_id, num_hosts) — restart/elastic-safe.
"""

from __future__ import annotations

import threading
import queue

from repro.data.synthetic import SyntheticTokens


class HostDataPipeline:
    """Background-thread prefetch of deterministic host batches."""

    def __init__(self, dataset: SyntheticTokens, host_id: int = 0, num_hosts: int = 1, prefetch: int = 2):
        self.dataset = dataset
        self.host_id = host_id
        self.num_hosts = num_hosts
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._next_step = 0

    def start(self, from_step: int = 0):
        self._next_step = from_step
        self._stop.clear()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()
        return self

    def _worker(self):
        step = self._next_step
        while not self._stop.is_set():
            batch = self.dataset.host_batch_at(step, self.host_id, self.num_hosts)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self):
        return self._q.get()

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            # drain so the worker unblocks
            try:
                while True:
                    self._q.get_nowait()
            except queue.Empty:
                pass
            self._thread.join(timeout=5)
