"""Parameter-spec system.

Models declare parameters as trees of :class:`ParamSpec` (shape + logical
axes + init recipe) rather than arrays.  This gives three consumers one
source of truth:

* ``materialize``  — real arrays for smoke tests / examples / training;
* ``abstract``     — ShapeDtypeStructs for the multi-pod dry-run (a 72B model
  is never allocated: ``jit(train_step).lower()`` takes the abstract tree);
* ``axes_tree``    — logical-axis tree consumed by ``repro.sharding.rules``
  to derive NamedShardings.

Logical axis vocabulary (mapped to mesh axes per workload in sharding/rules.py):
  "embed"   — d_model-sized dims (FSDP candidate)
  "heads"   — flattened attention projection output (n_heads*head_dim, TP)
  "kv"      — kv-head-sized dims
  "mlp"     — FFN hidden (TP)
  "vocab"   — vocabulary (TP)
  "experts" — MoE expert dim (EP)
  "layers"  — scanned layer stacks (never sharded)
  "state", "conv", None — small/replicated dims
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp


class ParamSpec(NamedTuple):
    shape: tuple
    axes: tuple  # logical axes, same length as shape
    init: str = "normal"  # "normal" | "zeros" | "ones"
    scale: float | None = None  # stddev for "normal"


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _flatten(specs):
    return jax.tree.flatten(specs, is_leaf=is_spec)


def materialize(specs, key, dtype=jnp.float32):
    """Instantiate real parameter arrays (deterministic per tree position)."""
    leaves, treedef = _flatten(specs)
    keys = jax.random.split(key, max(len(leaves), 1))
    arrs = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            arrs.append(jnp.zeros(s.shape, dtype))
        elif s.init == "ones":
            arrs.append(jnp.ones(s.shape, dtype))
        else:
            scale = s.scale if s.scale is not None else 0.02
            arrs.append(jax.random.normal(k, s.shape, jnp.float32).astype(dtype) * scale)
    return jax.tree.unflatten(treedef, arrs)


def abstract(specs, dtype=jnp.float32):
    """ShapeDtypeStruct tree — the dry-run stand-in (no allocation)."""
    leaves, treedef = _flatten(specs)
    return jax.tree.unflatten(
        treedef, [jax.ShapeDtypeStruct(s.shape, dtype) for s in leaves]
    )


def axes_tree(specs):
    """Tree of logical-axis tuples, same structure as the param tree."""
    leaves, treedef = _flatten(specs)
    return jax.tree.unflatten(treedef, [s.axes for s in leaves])


def stack(specs, n: int):
    """Prepend a scanned "layers" dimension to every spec in the subtree."""
    leaves, treedef = _flatten(specs)
    return jax.tree.unflatten(
        treedef,
        [ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale) for s in leaves],
    )


def dense_spec(d_in: int, d_out: int, axes=("embed", None), *, bias: bool = False, bias_axes=None):
    """A linear layer spec with fan-in init."""
    out = {"w": ParamSpec((d_in, d_out), axes, "normal", 1.0 / math.sqrt(d_in))}
    if bias:
        out["b"] = ParamSpec((d_out,), bias_axes if bias_axes is not None else (axes[-1],), "zeros")
    return out


def count_params(specs) -> int:
    leaves, _ = _flatten(specs)
    return sum(int(math.prod(s.shape)) for s in leaves)
