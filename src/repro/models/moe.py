"""Mixture-of-Experts FFN: top-k token-choice routing with sort-based
capacity dispatch (dropless up to the capacity factor).

Dispatch pipeline (all MXU/TPU-friendly, no (T, E, C) one-hot monsters):
  1. router logits -> top-k (gates, expert ids) per token;
  2. flatten to T*k assignments, sort by expert id (argsort = bitonic on TPU);
  3. rank-within-expert = position - first-occurrence (searchsorted over the
     sorted ids), tokens with rank >= capacity are dropped (GShard semantics);
  4. scatter token activations into an (E*C, d) buffer, batched expert GEMMs
     as einsum('ecd,edf->ecf') — the expert axis carries the "experts"
     logical axis so EP sharding falls out of the rule table;
  5. gather back by assignment, combine with gate weights.

Aux losses: load-balancing (Switch) + router-z, returned for the train step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec, dense_spec  # noqa: F401 (dense_spec used in moe_spec)
from repro.sharding.rules import logical_constraint


def moe_spec(cfg):
    d, dff, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    return {
        "router": dense_spec(d, e, ("embed", None)),
        "wi_gate": ParamSpec((e, d, dff), ("experts", "embed", "expert_mlp"), "normal", d**-0.5),
        "wi_up": ParamSpec((e, d, dff), ("experts", "embed", "expert_mlp"), "normal", d**-0.5),
        "wo": ParamSpec((e, dff, d), ("experts", "expert_mlp", "embed"), "normal", dff**-0.5),
    }


def moe(p, x, cfg, *, capacity_factor: float | None = None, n_groups: int | None = None):
    """x: (B, S, d) -> (y, aux) with aux = {"lb_loss", "z_loss"}.

    GShard-style GROUP-LOCAL dispatch: tokens are split into ``n_groups``
    independent routing groups (default: one per batch row, so the group axis
    inherits the batch sharding) and the sort/scatter/gather run *within*
    groups.  This is what keeps GSPMD sharding intact — a single global
    argsort over all tokens has no shardable dimension, so XLA replicates the
    whole dispatch AND the expert GEMMs on every device (measured: 16x
    per-device FLOPs on the mixtral train cell — EXPERIMENTS §Perf iteration
    B records the before/after).  Capacity is per (group, expert).
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.moe_top_k
    if capacity_factor is None:
        capacity_factor = cfg.moe_capacity_factor
    g = n_groups if n_groups is not None else b  # group axis ~ batch sharding
    t = (b * s) // g
    xf = x.reshape(g, t, d)
    xf = logical_constraint(xf, ("batch", None, "act_embed"))

    logits = (xf @ p["router"]["w"]).astype(jnp.float32)  # (G, T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, eidx = jax.lax.top_k(probs, k)  # (G, T, k)
    gates = gates / jnp.maximum(jnp.sum(gates, axis=-1, keepdims=True), 1e-9)

    # --- aux losses (Switch Transformer) ---
    me = jnp.mean(probs, axis=(0, 1))
    ce = jnp.mean(jax.nn.one_hot(eidx, e, dtype=jnp.float32).sum(2), axis=(0, 1))
    lb_loss = e * jnp.sum(me * ce / k)
    z_loss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)

    # --- group-local sort-based dispatch ---
    cap = int(max(k, (t * k) // e * capacity_factor)) if e > 0 else 0
    cap = max(cap, 1)
    flat_e = eidx.reshape(g, t * k)
    order = jnp.argsort(flat_e, axis=1)  # (G, T*k)
    sorted_e = jnp.take_along_axis(flat_e, order, axis=1)
    first = jax.vmap(lambda se: jnp.searchsorted(se, se, side="left"))(sorted_e)
    rank = jnp.arange(t * k)[None, :] - first
    keep = rank < cap
    dest = jnp.where(keep, sorted_e * cap + rank, e * cap)  # (G, T*k)
    tok_of = order // k

    gathered_in = jnp.take_along_axis(xf, tok_of[..., None], axis=1)  # (G, T*k, d)
    buf = jnp.zeros((g, e * cap + 1, d), x.dtype)
    buf = jax.vmap(lambda bb, dd, vv: bb.at[dd].set(vv))(buf, dest, gathered_in)
    buf = buf[:, :-1].reshape(g, e, cap, d)
    buf = logical_constraint(buf, ("batch", "experts", "cap", "act_embed"))

    # --- expert GEMMs (g: data-parallel, e: expert-parallel) ---
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buf, p["wi_gate"])) * jnp.einsum(
        "gecd,edf->gecf", buf, p["wi_up"]
    )
    h = logical_constraint(h, ("batch", "experts", "cap", "expert_mlp"))
    out = jnp.einsum("gecf,efd->gecd", h, p["wo"]).reshape(g, e * cap, d)
    out = jnp.concatenate([out, jnp.zeros((g, 1, d), out.dtype)], axis=1)

    # --- combine ---
    gathered = jnp.take_along_axis(out, dest[..., None], axis=1)  # (G, T*k, d)
    inv = jnp.argsort(order, axis=1)
    per_assign = jnp.take_along_axis(gathered, inv[..., None], axis=1).reshape(g, t, k, d)
    y = jnp.sum(per_assign * gates.astype(x.dtype)[..., None], axis=2)
    return y.reshape(b, s, d), {"lb_loss": lb_loss, "z_loss": z_loss}
