"""Model construction from ArchConfig."""

from __future__ import annotations

from repro.configs.base import ArchConfig
from repro.models.lm import DecoderLM, EncDecLM


def build_model(cfg: ArchConfig):
    if cfg.family == "audio":
        return EncDecLM(cfg)
    return DecoderLM(cfg)
