"""Transformer / Mamba / shared-hybrid blocks (pre-norm residual)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import attn_spec, attention
from repro.models.layers import mlp, mlp_spec, rmsnorm, rmsnorm_spec
from repro.models.params import ParamSpec
from repro.models.mamba2 import mamba, mamba_cache_spec, mamba_spec
from repro.models.moe import moe, moe_spec
from repro.sharding.rules import logical_constraint

ZERO_AUX = {"lb_loss": jnp.zeros((), jnp.float32), "z_loss": jnp.zeros((), jnp.float32)}


def block_spec(cfg, kind):
    mixer, ff = kind
    s = {}
    if mixer == "mamba":
        s["norm"] = rmsnorm_spec(cfg.d_model)
        s["mixer"] = mamba_spec(cfg)
    else:
        s["ln1"] = rmsnorm_spec(cfg.d_model)
        s["attn"] = attn_spec(cfg)
    if ff == "dense":
        s["ln2"] = rmsnorm_spec(cfg.d_model)
        s["mlp"] = mlp_spec(cfg.d_model, cfg.d_ff, cfg.act)
    elif ff == "moe":
        s["ln2"] = rmsnorm_spec(cfg.d_model)
        s["moe"] = moe_spec(cfg)
    return s


def block_cache_spec(cfg, kind, batch: int, seq: int):
    """(shape, logical_axes, dtype) leaves for one layer's decode cache.

    Sliding-window layers with cfg.windowed_cache hold a window-sized ring
    buffer instead of the full sequence (§Perf iteration E: 6x cache memory
    on gemma3 long_500k — 52 of 62 layers only ever attend 1024 back)."""
    mixer, _ = kind
    if mixer == "mamba":
        return mamba_cache_spec(cfg, batch)
    cache_len = seq
    if mixer == "local" and cfg.windowed_cache and cfg.sliding_window:
        cache_len = min(seq, cfg.sliding_window)
    kvshape = (batch, cache_len, cfg.n_kv_heads, cfg.head_dim)
    axes = ("batch", "cache_seq", "kv", None)
    return {"k": (kvshape, axes, jnp.bfloat16), "v": (kvshape, axes, jnp.bfloat16)}


def block_apply(p, x, kind, *, cfg, mode, cache=None, pos=None, positions=None, mrope_positions=None):
    """Returns (x, new_cache, aux)."""
    mixer, ff = kind
    aux = ZERO_AUX
    if mixer == "mamba":
        h, new_cache = mamba(p["mixer"], rmsnorm(p["norm"], x, cfg.norm_eps), cfg, mode=mode, cache=cache)
        x = x + h
    else:
        window = cfg.sliding_window if mixer == "local" else None
        h, new_cache = attention(
            p["attn"],
            rmsnorm(p["ln1"], x, cfg.norm_eps),
            cfg=cfg,
            mode=mode,
            positions=positions,
            mrope_positions=mrope_positions,
            window=window,
            causal=(mixer != "bidir"),
            use_rope=cfg.use_rope,
            cache=cache,
            pos=pos,
        )
        x = x + h
    if ff == "dense":
        x = x + mlp(p["mlp"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg.act)
    elif ff == "moe":
        h, aux = moe(p["moe"], rmsnorm(p["ln2"], x, cfg.norm_eps), cfg)
        x = x + h
    x = logical_constraint(x, ("batch", "seq", "act_embed"))
    return x, new_cache, aux


# ------------------------------------------------- Zamba-style shared block
def shared_block_spec(cfg):
    """One set of weights, applied at every shared_prefix group: attention +
    GLU MLP over concat(hidden, initial_embedding) (width 2*d_model), with a
    down-projection back into the residual stream."""
    d2 = cfg.shared_d
    return {
        "ln1": rmsnorm_spec(d2),
        "attn": attn_spec(cfg, d_in=d2, n_heads=cfg.n_heads, head_dim=cfg.shared_head_dim),
        "ln2": rmsnorm_spec(d2),
        "mlp": mlp_spec(d2, cfg.d_ff, cfg.act),
        "down": {"w": ParamSpec((d2, cfg.d_model), ("heads", "embed"), "normal", d2**-0.5)},
    }


def shared_block_cache_spec(cfg, batch: int, seq: int):
    kvshape = (batch, seq, cfg.n_heads, cfg.shared_head_dim)
    axes = ("batch", "cache_seq", "kv", None)
    return {"k": (kvshape, axes, jnp.bfloat16), "v": (kvshape, axes, jnp.bfloat16)}


def shared_block_apply(p, x, x0, *, cfg, mode, cache=None, pos=None, positions=None):
    """u = [x ; x0] -> attn -> mlp -> down-projected into the residual."""
    u = jnp.concatenate([x, x0], axis=-1)
    h, new_cache = attention(
        p["attn"],
        rmsnorm(p["ln1"], u, cfg.norm_eps),
        cfg=cfg,
        mode=mode,
        positions=positions,
        cache=cache,
        pos=pos,
        n_heads=cfg.n_heads,
    )
    u = u + h
    u = u + mlp(p["mlp"], rmsnorm(p["ln2"], u, cfg.norm_eps), cfg.act)
    x = x + u @ p["down"]["w"]
    return x, new_cache
