"""Mamba-2 (SSD — state-space duality, arXiv:2405.21060) in pure JAX.

Chunked matmul formulation: the sequence is split into chunks of Q tokens;
within a chunk the SSM is evaluated as a masked attention-like product
(MXU-friendly einsums), between chunks a (B, H, P, N) state is carried by a
short scan.  Decode carries the same state with an O(1) per-token update.

Layer structure follows the reference implementation:
  in_proj -> [z | xBC | dt]; causal depthwise conv over xBC; SSD core over
  (x, B, C, dt, A); gated RMSNorm (norm(y * silu(z))); out_proj.
ngroups = 1 (B, C shared across heads).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense, rmsnorm, rmsnorm_spec
from repro.models.params import ParamSpec, dense_spec
from repro.sharding.rules import logical_constraint


def mamba_dims(cfg):
    d_inner = cfg.ssm_expand * cfg.d_model
    nheads = d_inner // cfg.ssm_headdim
    conv_ch = d_inner + 2 * cfg.ssm_state
    return d_inner, nheads, cfg.ssm_headdim, cfg.ssm_state, conv_ch


def mamba_spec(cfg):
    d = cfg.d_model
    di, h, p_, n, conv_ch = mamba_dims(cfg)
    return {
        "in_proj": dense_spec(d, 2 * di + 2 * n + h, ("embed", "heads")),
        "conv_w": ParamSpec((cfg.ssm_conv, conv_ch), ("conv", "heads"), "normal", 0.2),
        "conv_b": ParamSpec((conv_ch,), ("heads",), "zeros"),
        "A_log": ParamSpec((h,), (None,), "zeros"),  # A = -exp(A_log), init -1
        "D": ParamSpec((h,), (None,), "ones"),
        "dt_bias": ParamSpec((h,), (None,), "zeros"),
        "norm": rmsnorm_spec(di),
        "out_proj": dense_spec(di, d, ("heads", "embed")),
    }


def _causal_conv(xbc, w, b):
    """Depthwise causal conv, width W, via W shifted adds (W is 4; unrolled)."""
    wlen = w.shape[0]
    out = jnp.zeros_like(xbc)
    for i in range(wlen):
        shift = wlen - 1 - i
        shifted = jnp.pad(xbc, ((0, 0), (shift, 0), (0, 0)))[:, : xbc.shape[1], :]
        out = out + shifted * w[i]
    return out + b


def _segsum_decay(dacs):
    """exp(cum_i - cum_j) masked to j <= i.  dacs: (B, C, Q, H) inclusive
    cumsum of dA.  Returns (B, C, H, Q, Q) in f32."""
    ci = dacs[:, :, :, None, :]  # (B,C,Q,1,H) -> i index
    cj = dacs[:, :, None, :, :]  # (B,C,1,Q,H) -> j index
    diff = (ci - cj).transpose(0, 1, 4, 2, 3)  # (B,C,H,Q,Q)
    q = dacs.shape[2]
    ii = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    mask = ii >= jj
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_scan(xh, bmat, cmat, dt, a_log, *, chunk: int, init_state=None):
    """SSD core.

    xh:   (B, L, H, P)   per-head inputs
    bmat: (B, L, N), cmat: (B, L, N)   shared across heads (ngroups=1)
    dt:   (B, L, H)      post-softplus step sizes
    a_log:(H,)           A = -exp(a_log)
    Returns (y (B, L, H, P), final_state (B, H, P, N)).
    """
    b, l, h, p_ = xh.shape
    n = bmat.shape[-1]
    q = chunk
    pad = (-l) % q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    lc = xh.shape[1]
    nc = lc // q
    xc = xh.reshape(b, nc, q, h, p_)
    bc = bmat.reshape(b, nc, q, n).astype(jnp.float32)
    cc = cmat.reshape(b, nc, q, n).astype(jnp.float32)
    dtc = dt.reshape(b, nc, q, h).astype(jnp.float32)

    a = -jnp.exp(a_log.astype(jnp.float32))  # (H,)
    da = dtc * a  # (B,C,Q,H)
    cum = jnp.cumsum(da, axis=2)  # inclusive
    dtx = (dtc[..., None] * xc.astype(jnp.float32))  # (B,C,Q,H,P)

    # ---- intra-chunk (quadratic within chunk, masked) ----
    cb = jnp.einsum("bcin,bcjn->bcij", cc, bc)  # (B,C,Q,Q)
    decay = _segsum_decay(cum)  # (B,C,H,Q,Q)
    y_intra = jnp.einsum("bcij,bchij,bcjhp->bcihp", cb, decay, dtx)

    # ---- chunk states ----
    end_decay = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,C,Q,H) decay j..end
    states = jnp.einsum("bcjn,bcjh,bcjhp->bchpn", bc, end_decay, dtx)  # (B,C,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,C,H)

    # ---- inter-chunk recurrence ----
    if init_state is None:
        init_state = jnp.zeros((b, h, p_, n), jnp.float32)
    else:
        init_state = init_state.astype(jnp.float32)

    def step(carry, inp):
        st = carry
        s_c, dk = inp  # (B,H,P,N), (B,H)
        entering = st
        st = st * dk[:, :, None, None] + s_c
        return st, entering

    final, entering = jax.lax.scan(
        step,
        init_state,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    entering = entering.transpose(1, 0, 2, 3, 4)  # (B,C,H,P,N)

    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp", cc, jnp.exp(cum), entering)
    y = (y_intra + y_inter).reshape(b, lc, h, p_)[:, :l]
    return y.astype(xh.dtype), final


def mamba(p, x, cfg, *, mode: str = "train", cache=None):
    """x (B, L, d).  Returns (y, new_cache); cache = {"ssm": (B,H,P,N) f32,
    "conv": (B, W-1, conv_ch)}."""
    b, l, d = x.shape
    di, h, p_, n, conv_ch = mamba_dims(cfg)
    zxbcdt = dense(p["in_proj"], x)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : di + conv_ch]
    dt_raw = zxbcdt[..., di + conv_ch :]  # (B,L,H)

    if mode == "decode":
        # single-token step against the cache
        conv_state = cache["conv"]  # (B, W-1, conv_ch)
        full = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)  # (B, W, ch)
        w = p["conv_w"]
        conv_out = jnp.einsum("bwc,wc->bc", full, w)[:, None, :] + p["conv_b"]
        new_conv = full[:, 1:, :]
        xbc_act = jax.nn.silu(conv_out)
        xs = xbc_act[..., :di].reshape(b, h, p_)
        bmat = xbc_act[..., di : di + n].reshape(b, n).astype(jnp.float32)
        cmat = xbc_act[..., di + n :].reshape(b, n).astype(jnp.float32)
        dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
        a = -jnp.exp(p["A_log"].astype(jnp.float32))
        dec = jnp.exp(dt * a)  # (B,H)
        st = cache["ssm"].astype(jnp.float32)
        dtx = dt[..., None] * xs.astype(jnp.float32)  # (B,H,P)
        st = st * dec[:, :, None, None] + jnp.einsum("bn,bhp->bhpn", bmat, dtx)
        y = jnp.einsum("bn,bhpn->bhp", cmat, st)
        y = y + p["D"].astype(jnp.float32)[None, :, None] * xs.astype(jnp.float32)
        y = y.reshape(b, 1, di).astype(x.dtype)
        y = rmsnorm(p["norm"], y * jax.nn.silu(z))
        return dense(p["out_proj"], y), {"ssm": st, "conv": new_conv}

    conv_out = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xbc_act = jax.nn.silu(conv_out)
    xs = xbc_act[..., :di].reshape(b, l, h, p_)
    bmat = xbc_act[..., di : di + n]
    cmat = xbc_act[..., di + n :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    y, final = ssd_scan(xs, bmat, cmat, dt, p["A_log"], chunk=cfg.ssm_chunk)
    y = y + p["D"].astype(x.dtype)[None, None, :, None] * xs
    y = y.reshape(b, l, di)
    y = logical_constraint(y, ("batch", "seq", "heads"))
    y = rmsnorm(p["norm"], y * jax.nn.silu(z))
    out = dense(p["out_proj"], y)
    new_cache = None
    if mode == "prefill":
        new_conv = xbc[:, l - (cfg.ssm_conv - 1) :, :] if l >= cfg.ssm_conv - 1 else jnp.pad(
            xbc, ((0, 0), (cfg.ssm_conv - 1 - l, 0), (0, 0))
        )
        new_cache = {"ssm": final, "conv": new_conv}
    return out, new_cache


def mamba_cache_spec(cfg, batch: int):
    """(shapes, axes) for the decode cache of one mamba layer."""
    di, h, p_, n, conv_ch = mamba_dims(cfg)
    return {
        "ssm": ((batch, h, p_, n), ("batch", "heads", None, "state"), jnp.float32),
        "conv": ((batch, cfg.ssm_conv - 1, conv_ch), ("batch", None, "heads"), jnp.bfloat16),
    }
