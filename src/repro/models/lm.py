"""Top-level models: DecoderLM (dense/MoE/SSM/hybrid/VLM) and EncDecLM
(Whisper-family).

Layers are organised as scanned stacks (``GroupDef``): parameters carry a
leading "layers" axis and the forward pass is a ``lax.scan`` over groups —
compile time is O(distinct group shapes), not O(n_layers), which is what
makes the 80-layer dry-runs tractable.

``apply`` returns final *hidden states*; logits/loss materialisation is the
step functions' business (so the (B, S, V) f32 tensor never exists in decode,
and the train step can chunk it).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, GroupDef
from repro.models import params as pm
from repro.models.attention import attn_spec, attention
from repro.models.blocks import (
    ZERO_AUX,
    block_apply,
    block_cache_spec,
    block_spec,
    shared_block_apply,
    shared_block_cache_spec,
    shared_block_spec,
)
from repro.models.layers import (
    embed,
    mlp,
    mlp_spec,
    rmsnorm,
    rmsnorm_spec,
    sinusoidal_positions,
)
from repro.sharding.rules import logical_constraint


def _add_aux(a, b):
    return jax.tree.map(lambda x, y: x + y, a, b)


def cast_params(p, dtype=jnp.bfloat16):
    """Cast float params to the compute dtype (master copies stay f32 in the
    optimizer; norms/softmax/SSM decays re-upcast internally)."""
    return jax.tree.map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a, p
    )


def _group_spec(cfg, gdef: GroupDef):
    return {f"l{i}": block_spec(cfg, kind) for i, kind in enumerate(gdef.pattern)}


def _group_cache_spec(cfg, gdef: GroupDef, batch, seq):
    out = {}
    for i, kind in enumerate(gdef.pattern):
        out[f"l{i}"] = block_cache_spec(cfg, kind, batch, seq)
    if gdef.shared_prefix:
        out["shared"] = shared_block_cache_spec(cfg, batch, seq)
    return out


def _stack_leaves(tree, n):
    """(shape, axes, dtype) leaves -> stacked with a leading layers dim."""

    def one(leaf):
        shape, axes, dtype = leaf
        return ((n,) + shape, ("layers",) + axes, dtype)

    return jax.tree.map(one, tree, is_leaf=lambda x: isinstance(x, tuple) and len(x) == 3 and isinstance(x[0], tuple))


class DecoderLM:
    """Decoder-only LM over ``cfg.groups`` (+ optional shared hybrid block,
    VLM patch-embedding merge, M-RoPE)."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    # ---------------------------------------------------------------- specs
    def spec(self):
        cfg = self.cfg
        s = {
            "embed": {"table": pm.ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), "normal", 0.02)},
            "ln_f": rmsnorm_spec(cfg.d_model),
            "stacks": {
                f"g{i}": pm.stack(_group_spec(cfg, g), g.repeats)
                for i, g in enumerate(cfg.groups)
            },
        }
        if cfg.shared_block:
            s["shared"] = shared_block_spec(cfg)
        if not cfg.tie_embeddings:
            s["unembed"] = {"w": pm.ParamSpec((cfg.d_model, cfg.vocab_size), ("embed", "vocab"), "normal", cfg.d_model**-0.5)}
        return s

    def cache_spec(self, batch: int, seq: int):
        cfg = self.cfg
        return {
            f"g{i}": _stack_leaves(_group_cache_spec(cfg, g, batch, seq), g.repeats)
            for i, g in enumerate(cfg.groups)
        }

    # -------------------------------------------------------------- forward
    def _embed_inputs(self, p, tokens, extra, mode, pos):
        cfg = self.cfg
        x = embed(p["embed"], tokens)
        if cfg.n_vis_tokens and extra is not None and "visual_embeds" in extra and mode != "decode":
            vis = extra["visual_embeds"].astype(x.dtype)  # (B, n_vis, d) patch stub
            x = jnp.concatenate([vis, x[:, cfg.n_vis_tokens :, :]], axis=1)
        return x

    def _positions(self, tokens, mode, pos, extra):
        cfg = self.cfg
        b, s = tokens.shape
        if mode == "decode":
            positions = jnp.full((b, s), pos, jnp.int32)
            mrope = (
                jnp.full((3, b, s), pos, jnp.int32) if cfg.mrope_sections else None
            )
        else:
            positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))
            mrope = None
            if cfg.mrope_sections:
                if extra is not None and "mrope_positions" in extra:
                    mrope = extra["mrope_positions"]
                else:
                    mrope = jnp.broadcast_to(positions[None], (3, b, s))
        return positions, mrope

    def apply(self, p, tokens, *, mode: str = "train", caches=None, pos=None, extra=None, remat: bool = False, unroll: bool = False):
        """tokens (B, S) int32 -> (hidden (B,S,d), new_caches, aux).

        unroll=True replaces the layer lax.scans with python loops — used by
        the dry-run cost-model pass only (XLA cost analysis counts while
        bodies once, so scanned stacks must be unrolled to be counted)."""
        cfg = self.cfg
        p = cast_params(p)
        x = self._embed_inputs(p, tokens, extra, mode, pos).astype(jnp.bfloat16)
        x = logical_constraint(x, ("batch", "seq", "act_embed"))
        positions, mrope = self._positions(tokens, mode, pos, extra)
        x0 = x  # initial embedding (Zamba shared-block input)
        aux = ZERO_AUX
        new_caches = {}

        for gi, gdef in enumerate(cfg.groups):
            gname = f"g{gi}"
            stack_params = p["stacks"][gname]
            stack_caches = caches[gname] if caches is not None else None

            def group_body(carry, scanned, gdef=gdef):
                xc, auxc = carry
                gp = scanned["params"]
                gc = scanned.get("cache")
                newc = {}
                if gdef.shared_prefix:
                    xc, sc = shared_block_apply(
                        p["shared"], xc, x0, cfg=cfg, mode=mode,
                        cache=(gc or {}).get("shared"), pos=pos, positions=positions,
                    )
                    if sc is not None:
                        newc["shared"] = sc
                for i, kind in enumerate(gdef.pattern):
                    xc, c, a = block_apply(
                        gp[f"l{i}"], xc, kind, cfg=cfg, mode=mode,
                        cache=(gc or {}).get(f"l{i}"), pos=pos,
                        positions=positions, mrope_positions=mrope,
                    )
                    if c is not None:
                        newc[f"l{i}"] = c
                    auxc = _add_aux(auxc, a)
                return (xc, auxc), newc

            body = jax.checkpoint(group_body) if remat else group_body
            xs = {"params": stack_params}
            if stack_caches is not None:
                xs["cache"] = stack_caches
            if unroll:
                outs = []
                carry = (x, aux)
                for j in range(gdef.repeats):
                    carry, nc = body(carry, jax.tree.map(lambda a: a[j], xs))
                    outs.append(nc)
                (x, aux) = carry
                newc = (
                    jax.tree.map(lambda *ls: jnp.stack(ls), *outs) if outs and outs[0] else {}
                )
            elif mode == "decode" and stack_caches is not None:
                # decode: carry the WHOLE stacked cache and update in place —
                # as a scan carry the buffer aliases under donation (as ys it
                # would double-buffer: +cache-size temp memory per step)
                def group_body_carry(carry, scanned, gdef=gdef):
                    xc, auxc, call = carry
                    j = scanned["idx"]
                    gc = jax.tree.map(
                        lambda a: jax.lax.dynamic_index_in_dim(a, j, 0, keepdims=False), call
                    )
                    (xc, auxc), newc = group_body((xc, auxc), {"params": scanned["params"], "cache": gc})
                    call = jax.tree.map(
                        lambda a, n: jax.lax.dynamic_update_index_in_dim(a, n.astype(a.dtype), j, 0),
                        call, newc,
                    )
                    return (xc, auxc, call), ()

                idxs = jnp.arange(gdef.repeats, dtype=jnp.int32)
                (x, aux, newc), _ = jax.lax.scan(
                    group_body_carry, (x, aux, stack_caches), {"params": stack_params, "idx": idxs}
                )
            else:
                (x, aux), newc = jax.lax.scan(body, (x, aux), xs)
            if newc:
                new_caches[gname] = newc

        x = rmsnorm(p["ln_f"], x, cfg.norm_eps)
        return x, (new_caches if new_caches else None), aux

    def logits(self, p, hidden):
        cfg = self.cfg
        if cfg.tie_embeddings:
            w = p["embed"]["table"].T.astype(hidden.dtype)
        else:
            w = p["unembed"]["w"].astype(hidden.dtype)
        out = hidden @ w
        out = logical_constraint(out, ("batch", "seq", "vocab"))
        return out.astype(jnp.float32)


# ---------------------------------------------------------------- Enc-Dec
class EncDecLM:
    """Whisper-family encoder-decoder.  The audio conv frontend is a STUB per
    the assignment: inputs are precomputed frame embeddings (B, S_enc, d);
    sinusoidal positions on both sides, no RoPE (matching Whisper)."""

    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def _enc_block_spec(self):
        cfg = self.cfg
        return {
            "ln1": rmsnorm_spec(cfg.d_model),
            "attn": attn_spec(cfg),
            "ln2": rmsnorm_spec(cfg.d_model),
            "mlp": mlp_spec(cfg.d_model, cfg.d_ff, cfg.act),
        }

    def _dec_block_spec(self):
        cfg = self.cfg
        return {
            "ln1": rmsnorm_spec(cfg.d_model),
            "attn": attn_spec(cfg),
            "lnx": rmsnorm_spec(cfg.d_model),
            "cross": attn_spec(cfg),
            "ln2": rmsnorm_spec(cfg.d_model),
            "mlp": mlp_spec(cfg.d_model, cfg.d_ff, cfg.act),
        }

    def spec(self):
        cfg = self.cfg
        n_dec = cfg.n_layers
        return {
            "embed": {"table": pm.ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"), "normal", 0.02)},
            "enc_stack": pm.stack(self._enc_block_spec(), cfg.n_enc_layers),
            "enc_ln_f": rmsnorm_spec(cfg.d_model),
            "dec_stack": pm.stack(self._dec_block_spec(), n_dec),
            "ln_f": rmsnorm_spec(cfg.d_model),
        }

    def cache_spec(self, batch: int, seq: int, enc_seq: int | None = None):
        cfg = self.cfg
        n_dec = cfg.n_layers
        enc_seq = enc_seq if enc_seq is not None else seq
        kvshape = (batch, seq, cfg.n_kv_heads, cfg.head_dim)
        xshape = (batch, enc_seq, cfg.n_kv_heads, cfg.head_dim)
        axes = ("batch", "cache_seq", "kv", None)
        one = {
            "self": {"k": (kvshape, axes, jnp.bfloat16), "v": (kvshape, axes, jnp.bfloat16)},
            "cross": {"k": (xshape, axes, jnp.bfloat16), "v": (xshape, axes, jnp.bfloat16)},
        }
        return _stack_leaves({"layers": one}, n_dec)

    def encode(self, p, frames, remat: bool = False, unroll: bool = False):
        cfg = self.cfg
        x = frames.astype(jnp.bfloat16)
        x = x + sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]
        x = logical_constraint(x, ("batch", "seq", "act_embed"))

        def body(carry, gp):
            xc = carry
            h, _ = attention(
                gp["attn"], rmsnorm(gp["ln1"], xc, cfg.norm_eps),
                cfg=cfg, mode="train", causal=False, use_rope=False,
            )
            xc = xc + h
            xc = xc + mlp(gp["mlp"], rmsnorm(gp["ln2"], xc, cfg.norm_eps), cfg.act)
            return xc, ()

        body = jax.checkpoint(body) if remat else body
        if unroll:
            for j in range(cfg.n_enc_layers):
                x, _ = body(x, jax.tree.map(lambda a: a[j], p["enc_stack"]))
        else:
            x, _ = jax.lax.scan(body, x, p["enc_stack"])
        return rmsnorm(p["enc_ln_f"], x, cfg.norm_eps)

    def apply(self, p, tokens, *, mode="train", frames=None, caches=None, pos=None, extra=None, remat=False, unroll=False):
        """Decoder pass.  train/prefill: frames required (encoder runs).
        decode: caches carry self+cross K/V; frames unused."""
        cfg = self.cfg
        p = cast_params(p)
        enc_out = None
        if mode in ("train", "prefill"):
            if frames is None and extra is not None:
                frames = extra.get("frames")
            enc_out = self.encode(p, frames, remat=remat, unroll=unroll)

        x = embed(p["embed"], tokens).astype(jnp.bfloat16)
        if mode == "decode":
            x = x + sinusoidal_positions(1, cfg.d_model, x.dtype, offset=pos)[None]
        else:
            x = x + sinusoidal_positions(x.shape[1], cfg.d_model, x.dtype)[None]

        def body(carry, scanned):
            xc = carry
            gp = scanned["params"]
            gc = scanned.get("cache")
            newc = {}
            h, selfc = attention(
                gp["attn"], rmsnorm(gp["ln1"], xc, cfg.norm_eps),
                cfg=cfg, mode=mode, causal=True, use_rope=False,
                cache=(gc or {}).get("self"), pos=pos,
            )
            xc = xc + h
            if selfc is not None:
                newc["self"] = selfc
            if mode == "decode":
                h, _ = attention(
                    gp["cross"], rmsnorm(gp["lnx"], xc, cfg.norm_eps),
                    cfg=cfg, mode=mode, causal=False, use_rope=False,
                    cache=gc["cross"], static_kv=True,
                )
                newc["cross"] = gc["cross"]
            else:
                h, crossc = attention(
                    gp["cross"], rmsnorm(gp["lnx"], xc, cfg.norm_eps),
                    cfg=cfg, mode=mode, causal=False, use_rope=False, kv_x=enc_out,
                )
                if crossc is not None:
                    newc["cross"] = crossc
            xc = xc + h
            xc = xc + mlp(gp["mlp"], rmsnorm(gp["ln2"], xc, cfg.norm_eps), cfg.act)
            xc = logical_constraint(xc, ("batch", "seq", "act_embed"))
            return xc, newc

        body = jax.checkpoint(body) if (remat and mode == "train") else body
        xs = {"params": p["dec_stack"]}
        stack_caches = None
        if caches is not None:
            stack_caches = caches["layers"] if "layers" in caches else caches
            xs["cache"] = stack_caches
        n_dec = cfg.n_layers
        if unroll:
            outs = []
            for j in range(n_dec):
                x, nc = body(x, jax.tree.map(lambda a: a[j], xs))
                outs.append(nc)
            newc = jax.tree.map(lambda *ls: jnp.stack(ls), *outs) if outs and outs[0] else {}
        elif mode == "decode" and stack_caches is not None:
            # in-place cache carry (see DecoderLM.apply): aliases under donation
            def body_carry(carry, scanned):
                xc, call = carry
                j = scanned["idx"]
                gc = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, j, 0, keepdims=False), call
                )
                xc, newc = body(xc, {"params": scanned["params"], "cache": gc})
                call = jax.tree.map(
                    lambda a, n: jax.lax.dynamic_update_index_in_dim(a, n.astype(a.dtype), j, 0),
                    call, newc,
                )
                return (xc, call), ()

            idxs = jnp.arange(n_dec, dtype=jnp.int32)
            (x, newc), _ = jax.lax.scan(
                body_carry, (x, stack_caches), {"params": p["dec_stack"], "idx": idxs}
            )
        else:
            x, newc = jax.lax.scan(body, x, xs)
        x = rmsnorm(p["ln_f"], x, cfg.norm_eps)
        new_caches = {"layers": newc} if newc else None
        return x, new_caches, ZERO_AUX

    def logits(self, p, hidden):
        out = hidden @ p["embed"]["table"].T.astype(hidden.dtype)
        out = logical_constraint(out, ("batch", "seq", "vocab"))
        return out.astype(jnp.float32)
