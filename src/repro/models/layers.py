"""Common neural-net layers (functional: spec() builders + apply functions).

All applies take plain array trees produced from the matching spec; compute
dtype is whatever the caller cast the params/activations to (bf16 in the
production steps), with norms and softmax internally upcast to f32.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.params import ParamSpec, dense_spec
from repro.sharding.rules import logical_constraint


# --------------------------------------------------------------------- norms
def rmsnorm_spec(d: int):
    return {"scale": ParamSpec((d,), (None,), "ones")}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_spec(d: int):
    return {"scale": ParamSpec((d,), (None,), "ones"), "bias": ParamSpec((d,), (None,), "zeros")}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


# -------------------------------------------------------------------- dense
def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# ---------------------------------------------------------------- embeddings
def embedding_spec(vocab: int, d: int):
    return {"table": ParamSpec((vocab, d), ("vocab", "embed"), "normal", 0.02)}


def embed(p, ids):
    return jnp.take(p["table"], ids, axis=0)


def unembed(p, x):
    """Tied unembedding: logits in f32 (the long-reduction softmax path)."""
    return (x @ p["table"].T.astype(x.dtype)).astype(jnp.float32)


def sinusoidal_positions(seq_len: int, d: int, dtype=jnp.float32, offset=0):
    # offset may be a traced scalar (decode position)
    pos = (jnp.arange(seq_len, dtype=jnp.float32) + offset)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    return pe.astype(dtype)


# --------------------------------------------------------------------- RoPE
def _rope_angles(positions, half: int, theta: float):
    # positions (..., S) -> (..., S, half)
    freqs = jnp.power(theta, -jnp.arange(half, dtype=jnp.float32) / half)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (B, S, H, hd); positions: (B, S)."""
    half = x.shape[-1] // 2
    ang = _rope_angles(positions, half, theta)  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def apply_mrope(x, positions, sections, theta: float = 10000.0):
    """M-RoPE (Qwen2-VL): positions (3, B, S) for (t, h, w) streams; the
    rotary half-dim is split into ``sections`` (summing to head_dim//2), each
    section driven by its own position stream."""
    half = x.shape[-1] // 2
    assert sum(sections) == half, (sections, half)
    freqs = jnp.power(theta, -jnp.arange(half, dtype=jnp.float32) / half)
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        ang = positions[i].astype(jnp.float32)[..., None] * freqs[start : start + sec]
        parts.append(ang)
        start += sec
    ang = jnp.concatenate(parts, axis=-1)  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(ang)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


# ----------------------------------------------------------------- MLP / GLU
def mlp_spec(d: int, d_ff: int, act: str = "swiglu"):
    if act in ("swiglu", "geglu"):
        return {
            "wi_gate": dense_spec(d, d_ff, ("embed", "mlp")),
            "wi_up": dense_spec(d, d_ff, ("embed", "mlp")),
            "wo": dense_spec(d_ff, d, ("mlp", "embed")),
        }
    return {
        "wi": dense_spec(d, d_ff, ("embed", "mlp")),
        "wo": dense_spec(d_ff, d, ("mlp", "embed")),
    }


def mlp(p, x, act: str = "swiglu"):
    if act == "swiglu":
        h = jax.nn.silu(dense(p["wi_gate"], x)) * dense(p["wi_up"], x)
    elif act == "geglu":
        h = jax.nn.gelu(dense(p["wi_gate"], x), approximate=True) * dense(p["wi_up"], x)
    else:
        h = jax.nn.gelu(dense(p["wi"], x), approximate=True)
    h = logical_constraint(h, ("batch", "seq", "mlp"))
    return dense(p["wo"], h)
