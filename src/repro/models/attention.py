"""GQA attention with sliding-window, cross-attention, RoPE/M-RoPE and a
(train | prefill | decode) cache protocol.

Cache layout: {"k": (B, S_max, KV, hd), "v": ...} in bf16.  Decode writes the
new token at position ``pos`` via dynamic_update_slice and attends over the
full cache with an iota mask — the cache's ``S_max`` axis carries the
"cache_seq" logical axis so the decode/long rule sets context-parallelise it
(GSPMD inserts the partial-softmax all-reduce).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import apply_mrope, apply_rope, dense
from repro.models.params import dense_spec
from repro.sharding.rules import logical_constraint

NEG_INF = -0.7 * float(jnp.finfo(jnp.float32).max)


def attn_spec(cfg, d_in: int | None = None, n_heads: int | None = None, head_dim: int | None = None):
    d = d_in if d_in is not None else cfg.d_model
    nh = n_heads if n_heads is not None else cfg.n_heads
    hd = head_dim if head_dim is not None else cfg.head_dim
    nkv = cfg.n_kv_heads if n_heads is None else nh  # overridden heads => MHA
    return {
        "wq": dense_spec(d, nh * hd, ("embed", "heads"), bias=cfg.qkv_bias),
        "wk": dense_spec(d, nkv * hd, ("embed", "kv"), bias=cfg.qkv_bias),
        "wv": dense_spec(d, nkv * hd, ("embed", "kv"), bias=cfg.qkv_bias),
        "wo": dense_spec(nh * hd, d, ("heads", "embed")),
    }


def _split_heads(x, n):
    b, s, _ = x.shape
    return x.reshape(b, s, n, -1)


def _attend(q, k, v, mask):
    """q (B,S,Hq,hd), k/v (B,T,KV,hd), mask broadcastable to (B,KV,G,S,T).
    Softmax in f32."""
    b, s, hq, hd = q.shape
    kv = k.shape[2]
    g = hq // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg, k).astype(jnp.float32)
    scores = scores * (1.0 / math.sqrt(hd))
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return o.reshape(b, s, hq * hd)


def _causal_mask(s, t, window):
    qpos = jax.lax.broadcasted_iota(jnp.int32, (s, t), 0)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (s, t), 1)
    m = kpos <= qpos
    if window is not None:
        m = m & (kpos > qpos - window)
    return m[None, None, None]  # (1,1,1,S,T)


def _decode_mask(t, pos, window):
    kpos = jax.lax.broadcasted_iota(jnp.int32, (1, t), 1)
    m = kpos <= pos
    if window is not None:
        m = m & (kpos > pos - window)
    return m[None, None, None]  # (1,1,1,1,T)


def attention(
    p,
    x,
    *,
    cfg,
    mode: str,
    positions=None,
    mrope_positions=None,
    window: int | None = None,
    causal: bool = True,
    use_rope: bool = True,
    cache=None,
    pos=None,
    kv_x=None,
    cache_dtype=jnp.bfloat16,
    n_heads: int | None = None,
    static_kv: bool = False,
):
    """Returns (out, new_cache).  new_cache is None in train mode.

    kv_x: source of K/V for cross-attention (encoder output).  In decode
    mode with kv_x=None the cache is read+updated; cross caches (encoder
    K/V precomputed at prefill) are read-only: pass static_kv=True.
    """
    b, s, _ = x.shape
    nh = n_heads if n_heads is not None else cfg.n_heads
    q = _split_heads(dense(p["wq"], x), nh)
    hd = q.shape[-1]

    if static_kv:  # cross-attn decode against a frozen cache
        k, v = cache["k"], cache["v"]
        k = k.astype(x.dtype)
        v = v.astype(x.dtype)
        mask = jnp.ones((1, 1, 1, s, k.shape[1]), bool)
        o = _attend(q, k, v, mask)
        return dense(p["wo"], o), cache

    src = kv_x if kv_x is not None else x
    kv_heads = p["wk"]["w"].shape[1] // hd
    k = _split_heads(dense(p["wk"], src), kv_heads)
    v = _split_heads(dense(p["wv"], src), kv_heads)

    if use_rope and kv_x is None:
        if mrope_positions is not None:
            q = apply_mrope(q, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
            k = apply_mrope(k, mrope_positions, cfg.mrope_sections, cfg.rope_theta)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

    if mode == "decode" and cache is not None:
        # write the new token, attend over the cache.  A cache shorter than
        # the sequence is a RING BUFFER (windowed local-attention layers):
        # slot = pos % L holds exactly the last L positions — attention is
        # permutation-invariant over keys, so slot order never matters, and
        # the recency window is enforced by the buffer size itself.
        t = cache["k"].shape[1]
        write_pos = jnp.remainder(pos, t) if window is not None and t <= window else pos
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, write_pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, write_pos, 0, 0))
        new_cache = {"k": ck, "v": cv}
        if window is not None and t <= window:
            # ring buffer: all resident slots are in-window; mask only the
            # not-yet-written slots (iota <= pos is all-true once pos >= t)
            mask = _decode_mask(t, pos, None)
        else:
            mask = _decode_mask(t, pos, window)
        o = _attend(q, ck.astype(x.dtype), cv.astype(x.dtype), mask)
        return dense(p["wo"], o), new_cache

    # train / prefill (full sequence)
    t = k.shape[1]
    if kv_x is not None or not causal:
        mask = jnp.ones((1, 1, 1, s, t), bool)
    else:
        mask = _causal_mask(s, t, window)
    o = _attend(q, k, v, mask)
    o = logical_constraint(o, ("batch", "seq", "heads"))
    out = dense(p["wo"], o)
    new_cache = None
    if mode == "prefill":
        new_cache = {"k": k.astype(cache_dtype), "v": v.astype(cache_dtype)}
    return out, new_cache


def init_cache_spec(cfg, batch: int, seq: int, *, n_heads=None, head_dim=None):
    """ShapeDtypeStruct + logical axes for one layer's KV cache."""
    kv = cfg.n_kv_heads if n_heads is None else n_heads
    hd = head_dim if head_dim is not None else cfg.head_dim
    shape = (batch, seq, kv, hd)
    axes = ("batch", "cache_seq", "kv", None)
    return shape, axes
